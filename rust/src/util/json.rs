//! Minimal JSON parser/serialiser (offline environment ships no serde).
//!
//! Supports the full JSON grammar we exchange with the python build path:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Used for artifacts/manifest.json, run configs, and results files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Shape-style array-of-arrays-of-numbers -> `Vec<Vec<usize>>`.
    pub fn as_shape_list(&self) -> Option<Vec<Vec<usize>>> {
        self.as_arr()?
            .iter()
            .map(|row| {
                row.as_arr()?
                    .iter()
                    .map(Json::as_usize)
                    .collect::<Option<Vec<_>>>()
            })
            .collect()
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(f64::from(x))).collect())
    }

    // ---- serialisation ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        if (0xd800..0xdc00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad \\u"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy raw bytes and re-validate
                    let start = self.pos - 1;
                    let len = match b {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + match d {
                    b'0'..=b'9' => u32::from(d - b'0'),
                    b'a'..=b'f' => u32::from(d - b'a' + 10),
                    b'A'..=b'F' => u32::from(d - b'A' + 10),
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").idx(1), &Json::Num(2.0));
        assert_eq!(j.get("a").idx(2).get("b"), &Json::Bool(false));
        assert_eq!(j.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn missing_paths_are_null() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(j.get("nope").get("deeper").idx(3), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"esc":"a\"b\\c\n","flag":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        let j2 = Json::parse(&out).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::obj(vec![
            ("xs", Json::from_f64s(&[1.0, 2.0])),
            ("name", Json::Str("fig4".into())),
        ]);
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("12ab").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn shape_list() {
        let j = Json::parse("[[256,32],[],[1,2048]]").unwrap();
        assert_eq!(
            j.as_shape_list().unwrap(),
            vec![vec![256, 32], vec![], vec![1, 2048]]
        );
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
