//! ASCII table / CSV rendering for the experiment harness — every paper
//! table and figure is emitted both as an aligned console table and as a
//! CSV under results/ for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String| {
            for wi in &w {
                let _ = write!(out, "+{}", "-".repeat(wi + 2));
            }
            let _ = writeln!(out, "+");
        };
        let emit = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let pad = w[i] - c.chars().count();
                let _ = write!(out, "| {}{} ", c, " ".repeat(pad));
            }
            let _ = writeln!(out, "|");
        };
        line(&mut out);
        emit(&mut out, &self.header);
        line(&mut out);
        for r in &self.rows {
            emit(&mut out, r);
        }
        line(&mut out);
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Render an ASCII line plot (rows of `series` share the x axis) — used
/// for the figure harnesses so gain responses are eyeballable in the
/// terminal next to the CSV dump.
pub fn ascii_plot(title: &str, xs: &[f64], series: &[(&str, Vec<f64>)], height: usize) -> String {
    let width = 72usize.min(xs.len().max(2));
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (ymax - ymin).max(1e-12);
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for col in 0..width {
            let idx = col * (ys.len() - 1) / (width - 1).max(1);
            let yn = (ys[idx] - ymin) / span;
            let row = ((1.0 - yn) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col] = marks[si % marks.len()];
        }
    }
    let mut out = format!("-- {title} --\n");
    for (r, row) in grid.iter().enumerate() {
        let yval = ymax - span * r as f64 / (height - 1) as f64;
        let _ = writeln!(out, "{yval:>10.3} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(
        out,
        "{:>10} +{}",
        "",
        "-".repeat(width)
    );
    let _ = writeln!(
        out,
        "{:>10}  x: {:.1} .. {:.1}   {}",
        "",
        xs.first().copied().unwrap_or(0.0),
        xs.last().copied().unwrap_or(0.0),
        series
            .iter()
            .enumerate()
            .map(|(i, (n, _))| format!("[{}]={}", marks[i % marks.len()], n))
            .collect::<Vec<_>>()
            .join("  ")
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["class", "train", "test"]);
        t.row(vec!["dog".into(), "91".into(), "94".into()]);
        t.row(vec!["sea_waves".into(), "88".into(), "88".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| sea_waves |"));
        // all data lines same width
        let lens: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("c", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn plot_smoke() {
        let xs: Vec<f64> = (0..50).map(f64::from).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (x / 8.0).sin()).collect();
        let s = ascii_plot("sine", &xs, &[("sin", ys)], 10);
        assert!(s.contains("sine"));
        assert!(s.lines().count() >= 12);
    }
}
