//! Std-only infrastructure: the offline environment has no serde / clap /
//! rand / proptest, so the equivalents live here (see DESIGN.md §4).

pub mod cli;
pub mod json;
pub mod logging;
pub mod par;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
