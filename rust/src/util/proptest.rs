//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |g| { ... })` runs a closure over `cases` random
//! generators seeded deterministically from the test name; on failure it
//! reports the failing case's seed so the case can be replayed with
//! `Gen::replay(seed)` in a focused unit test.

use super::prng::{Pcg32, SplitMix64};

/// Per-case value generator handed to property bodies.
pub struct Gen {
    pub rng: Pcg32,
    pub seed: u64,
}

impl Gen {
    pub fn replay(seed: u64) -> Gen {
        Gen {
            rng: Pcg32::new(seed),
            seed,
        }
    }

    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u32) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn f32(&mut self, lo: f64, hi: f64) -> f32 {
        self.rng.range(lo, hi) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Vector of normals with the given scale — the workhorse input for
    /// numeric properties; occasionally salts in adversarial values
    /// (zeros, ties, large magnitudes) which plain normal sampling would
    /// almost never produce.
    pub fn signal(&mut self, n: usize, scale: f64) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n)
            .map(|_| (self.rng.normal() * scale) as f32)
            .collect();
        if n >= 2 && self.rng.below(4) == 0 {
            // adversarial salt: duplicate an element (tie) and zero another
            let i = self.rng.below(n as u32) as usize;
            let j = self.rng.below(n as u32) as usize;
            v[i] = v[j];
            let k = self.rng.below(n as u32) as usize;
            v[k] = 0.0;
        }
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

/// Run `cases` random cases of `body`. Panics (failing the enclosing
/// #[test]) with the case seed on the first failure.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u32, mut body: F) {
    let mut h = SplitMix64::new(0xb10c_ab1e);
    for b in name.bytes() {
        h.next();
        h = SplitMix64::new(h.next() ^ u64::from(b));
    }
    let base = h.next();
    for case in 0..cases {
        let seed = base ^ (u64::from(case).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut g = Gen::replay(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut g)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 Gen::replay({seed:#x})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("abs-nonneg", 50, |g| {
            let x = g.f64(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::replay(42);
        for _ in 0..100 {
            let v = g.int(-3, 3);
            assert!((-3..=3).contains(&v));
            let u = g.usize(1, 5);
            assert!((1..=5).contains(&u));
            let f = g.f64(0.5, 2.0);
            assert!((0.5..2.0).contains(&f));
        }
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn check_reports_failures() {
        check("always-fails", 3, |g| {
            let x = g.f64(1.0, 2.0);
            assert!(x < 0.0, "x was {x}");
        });
    }

    #[test]
    fn signal_salting_produces_zeros_sometimes() {
        let mut zeros = 0;
        for case in 0..40 {
            let mut g = Gen::replay(case);
            let v = g.signal(16, 1.0);
            if v.contains(&0.0) {
                zeros += 1;
            }
        }
        assert!(zeros > 0);
    }
}
