//! Feature extraction front ends (paper Appendix A): HWR + accumulate +
//! standardise over a filter bank. Three interchangeable back ends:
//!
//! * conventional multirate MAC FIR (float baseline, Table III "Normal
//!   SVM floating point" inputs, Fig. 4b),
//! * direct full-rate high-order FIR bank (Fig. 4a comparator),
//! * float MP bank (`crate::mp::filter`) — the CPU mirror of the HLO
//!   `mp_frame_features` artifact the coordinator runs. Its per-sample
//!   MP-FIR step is the shared `crate::mp::kernel` core, the same code
//!   `CpuEngine` block-processes, so training-time features and the
//!   serving path agree by construction.

use crate::dsp::fir::FirFilter;
use crate::dsp::multirate::{BandPlan, MultirateFirBank};
use crate::mp::filter::MpMultirateBank;
use crate::util::par::par_map;

/// HWR + accumulate a set of per-band signals (paper eqs. 10-11).
pub fn hwr_accumulate(bands: &[Vec<f32>]) -> Vec<f32> {
    bands
        .iter()
        .map(|ys| ys.iter().map(|&y| y.max(0.0)).sum::<f32>())
        .collect()
}

/// Conventional multirate FIR features for one clip (fresh filter state).
pub fn fir_features(plan: &BandPlan, clip: &[f32]) -> Vec<f32> {
    let mut bank = MultirateFirBank::new(plan);
    hwr_accumulate(&bank.process(clip))
}

/// Float MP multirate features for one clip (fresh state) — CPU mirror of
/// the `mp_frame_features` HLO path.
pub fn mp_features(plan: &BandPlan, gamma_f: f32, clip: &[f32]) -> Vec<f32> {
    let mut bank = MpMultirateBank::new(plan, gamma_f);
    bank.features(clip)
}

/// Direct full-rate bank features (orders 15..200 per octave, Fig. 4a).
pub fn direct_features(plan: &BandPlan, clip: &[f32]) -> Vec<f32> {
    let coeffs = plan.direct_bp_coeffs();
    coeffs
        .iter()
        .map(|h| {
            let mut f = FirFilter::new(h.clone());
            f.process(clip).iter().map(|&y| y.max(0.0)).sum::<f32>()
        })
        .collect()
}

/// Parallel batch extraction over clips with any per-clip extractor.
pub fn extract_batch<F>(clips: &[crate::datasets::Clip], threads: usize, f: F) -> Vec<Vec<f32>>
where
    F: Fn(&[f32]) -> Vec<f32> + Sync,
{
    par_map(clips, threads, |c| f(&c.samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::esc10;
    use crate::dsp::chirp;
    use crate::util::stats::argmax;

    /// frequency distance in octaves between two bands of the plan
    fn band_dist(plan: &BandPlan, a: usize, b: usize) -> f64 {
        let bands = plan.bands();
        (bands[a].center_hz / bands[b].center_hz).log2().abs()
    }

    /// Octave o accumulates over len/2^o samples, so raw Phi is
    /// rate-imbalanced across octaves (the paper's per-band
    /// standardisation, eq. 12, absorbs this at inference time). For
    /// argmax checks, compensate by the decimation factor.
    fn rate_compensate(plan: &BandPlan, phi: &[f32]) -> Vec<f32> {
        phi.iter()
            .enumerate()
            .map(|(p, &v)| v * (1u32 << (p / plan.filters_per_octave)) as f32)
            .collect()
    }

    #[test]
    fn fir_features_peak_in_tone_band() {
        let plan = BandPlan::paper_default();
        let bands = plan.bands();
        let clip = chirp::tone(bands[12].center_hz, 16_384, plan.sample_rate, 0.8);
        let phi = rate_compensate(&plan, &fir_features(&plan, &clip));
        assert!(
            band_dist(&plan, argmax(&phi), 12) <= 0.55,
            "best {} for band 12",
            argmax(&phi)
        );
    }

    #[test]
    fn direct_and_multirate_agree_on_band_ranking() {
        // Fig. 4 claim: multirate order-15 matches direct high-order —
        // the excited band is the same to within half an octave (the
        // order-15 filters are shallow by design)
        let plan = BandPlan::paper_default();
        let bands = plan.bands();
        for p in [2usize, 8, 17, 27] {
            let clip = chirp::tone(bands[p].center_hz, 16_384, plan.sample_rate, 0.8);
            let multi = rate_compensate(&plan, &fir_features(&plan, &clip));
            let direct = direct_features(&plan, &clip);
            assert!(
                band_dist(&plan, argmax(&multi), p) <= 0.55,
                "multi argmax {} for band {p}",
                argmax(&multi)
            );
            assert!(
                band_dist(&plan, argmax(&direct), p) <= 0.35,
                "direct argmax {} for band {p}",
                argmax(&direct)
            );
        }
    }

    #[test]
    fn mp_features_nonnegative_and_informative() {
        let plan = BandPlan::paper_default();
        let a = mp_features(&plan, 1.0, &esc10::synth_clip(1, 2, 0).samples);
        let b = mp_features(&plan, 1.0, &esc10::synth_clip(1, 1, 0).samples);
        assert_eq!(a.len(), 30);
        assert!(a.iter().all(|&x| x >= 0.0));
        // sea_waves (low-band) vs rain (high-band): low/high energy ratios differ
        let ratio = |v: &[f32]| {
            let low: f32 = v[20..30].iter().sum();
            let high: f32 = v[0..10].iter().sum();
            f64::from(low) / f64::from(high.max(1e-9))
        };
        assert!(ratio(&a) > ratio(&b), "sea {} rain {}", ratio(&a), ratio(&b));
    }

    #[test]
    fn extract_batch_parallel_matches_serial() {
        let plan = BandPlan::paper_default();
        let clips: Vec<_> = (0..6).map(|i| esc10::synth_clip(2, i % 10, i as u64)).collect();
        let par = extract_batch(&clips, 4, |c| fir_features(&plan, c));
        let ser = extract_batch(&clips, 1, |c| fir_features(&plan, c));
        assert_eq!(par, ser);
    }
}
