//! Saturating interval arithmetic over `i128` — the abstract domain of
//! the bit-width prover.
//!
//! Every datapath value the fixed-point pipeline can produce is an i64;
//! the analyzer tracks a closed interval `[lo, hi]` ⊇ the set of values a
//! stage can take, in i128 so that no transfer function can itself wrap.
//! All operations are *outer* approximations: if `x ∈ X` and `y ∈ Y`
//! then `x op y ∈ X.op(Y)`. Operations saturate at the i128 range, which
//! only ever widens an interval — widening is always sound (the report
//! would then simply demand more bits than any register provides).

use crate::fixed::q::QFormat;

/// Closed integer interval `[lo, hi]` with `lo <= hi`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: i128,
    pub hi: i128,
}

impl Interval {
    pub fn new(lo: i128, hi: i128) -> Interval {
        assert!(lo <= hi, "interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// The single-point interval `[v, v]`.
    pub fn point(v: i128) -> Interval {
        Interval { lo: v, hi: v }
    }

    /// The full representable range of a W-bit register in format `f`.
    pub fn of_format(f: QFormat) -> Interval {
        Interval {
            lo: i128::from(f.min_q()),
            hi: i128::from(f.max_q()),
        }
    }

    /// Tight hull of a non-empty set of concrete values (e.g. the actual
    /// quantised filter taps or trained weights).
    pub fn of_values(vs: &[i64]) -> Interval {
        assert!(!vs.is_empty(), "of_values on empty slice");
        let lo = vs.iter().copied().min().unwrap_or(0);
        let hi = vs.iter().copied().max().unwrap_or(0);
        Interval {
            lo: i128::from(lo),
            hi: i128::from(hi),
        }
    }

    pub fn add(self, o: Interval) -> Interval {
        Interval {
            lo: self.lo.saturating_add(o.lo),
            hi: self.hi.saturating_add(o.hi),
        }
    }

    pub fn sub(self, o: Interval) -> Interval {
        Interval {
            lo: self.lo.saturating_sub(o.hi),
            hi: self.hi.saturating_sub(o.lo),
        }
    }

    pub fn neg(self) -> Interval {
        Interval {
            lo: self.hi.saturating_neg(),
            hi: self.lo.saturating_neg(),
        }
    }

    /// Smallest interval containing both operands (set union hull).
    pub fn union(self, o: Interval) -> Interval {
        Interval {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    /// Half-wave rectification `max(x, 0)` — the HWR stage before the
    /// kernel accumulator.
    pub fn hwr(self) -> Interval {
        Interval {
            lo: self.lo.max(0),
            hi: self.hi.max(0),
        }
    }

    /// `n * x` for a non-negative repeat count (accumulating `x` at most
    /// `n` times when `x >= 0`, or bounding a sum of `n` terms from `x`).
    pub fn scale(self, n: i128) -> Interval {
        assert!(n >= 0, "scale count {n}");
        Interval {
            lo: self.lo.saturating_mul(n),
            hi: self.hi.saturating_mul(n),
        }
    }

    /// Arithmetic right shift (floor division by 2^sh) — monotone, so it
    /// maps endpoints to endpoints.
    pub fn shr_floor(self, sh: u32) -> Interval {
        let sh = sh.min(126);
        Interval {
            lo: self.lo >> sh,
            hi: self.hi >> sh,
        }
    }

    /// Round-to-nearest (half-up) right shift, matching
    /// [`crate::fixed::q::CsdScale::apply`]: `(x + 2^(sh-1)) >> sh`.
    /// Monotone in `x`.
    pub fn shr_round(self, sh: u32) -> Interval {
        if sh == 0 {
            return self;
        }
        let sh = sh.min(126);
        let half = 1i128 << (sh - 1);
        Interval {
            lo: self.lo.saturating_add(half) >> sh,
            hi: self.hi.saturating_add(half) >> sh,
        }
    }

    /// Left shift (multiplication by 2^sh), saturating.
    pub fn shl(self, sh: u32) -> Interval {
        let sh = sh.min(126);
        let f = 1i128.checked_shl(sh).unwrap_or(i128::MAX);
        self.scale_signed(f)
    }

    fn scale_signed(self, f: i128) -> Interval {
        let a = self.lo.saturating_mul(f);
        let b = self.hi.saturating_mul(f);
        Interval {
            lo: a.min(b),
            hi: a.max(b),
        }
    }

    /// Clamp into the representable range of `f` — the effect of a
    /// saturating register write ([`QFormat::saturate`]).
    pub fn clamp_to(self, f: QFormat) -> Interval {
        let r = Interval::of_format(f);
        Interval {
            lo: self.lo.clamp(r.lo, r.hi),
            hi: self.hi.clamp(r.lo, r.hi),
        }
    }

    pub fn contains(self, v: i64) -> bool {
        let v = i128::from(v);
        self.lo <= v && v <= self.hi
    }

    pub fn contains_interval(self, o: Interval) -> bool {
        self.lo <= o.lo && o.hi <= self.hi
    }

    /// Two's-complement bits needed to represent every value in the
    /// interval: `max(bits_for(lo), bits_for(hi))`.
    pub fn bits_needed(self) -> u32 {
        bits_for(self.lo).max(bits_for(self.hi))
    }
}

/// Minimum two's-complement width (sign bit included) that represents
/// `v` exactly: 1 for {-1, 0}, 8 for 127 and -128, 9 for 128 and -129.
pub fn bits_for(v: i128) -> u32 {
    let magnitude = if v >= 0 { v as u128 } else { !v as u128 };
    (128 - magnitude.leading_zeros()).saturating_add(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn bits_for_twos_complement_boundaries() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(-1), 1);
        assert_eq!(bits_for(1), 2);
        assert_eq!(bits_for(-2), 2);
        assert_eq!(bits_for(127), 8);
        assert_eq!(bits_for(128), 9);
        assert_eq!(bits_for(-128), 8);
        assert_eq!(bits_for(-129), 9);
        assert_eq!(bits_for(511), 10);
        assert_eq!(bits_for(-512), 10);
        assert_eq!(bits_for(i128::from(i64::MAX)), 64);
        assert_eq!(bits_for(i128::from(i64::MIN)), 64);
    }

    #[test]
    fn format_interval_needs_exactly_w_bits() {
        for bits in 2..=32u32 {
            let f = QFormat::new(bits, 0);
            assert_eq!(Interval::of_format(f).bits_needed(), bits);
        }
    }

    #[test]
    fn transfer_functions_are_outer_approximations() {
        check("interval-soundness", 200, |g| {
            let (a_lo, a_hi) = {
                let x = g.int(-10_000, 10_000);
                let y = g.int(-10_000, 10_000);
                (x.min(y), x.max(y))
            };
            let (b_lo, b_hi) = {
                let x = g.int(-10_000, 10_000);
                let y = g.int(-10_000, 10_000);
                (x.min(y), x.max(y))
            };
            let a = Interval::new(i128::from(a_lo), i128::from(a_hi));
            let b = Interval::new(i128::from(b_lo), i128::from(b_hi));
            // concrete members
            let x = g.int(a_lo, a_hi);
            let y = g.int(b_lo, b_hi);
            assert!(a.add(b).contains(x + y));
            assert!(a.sub(b).contains(x - y));
            assert!(a.neg().contains(-x));
            assert!(a.union(b).contains(x) && a.union(b).contains(y));
            assert!(a.hwr().contains(x.max(0)));
            let sh = g.usize(0, 8) as u32;
            assert!(a.shr_floor(sh).contains(x >> sh));
            assert!(a.shl(sh).contains(x << sh));
            if sh > 0 {
                assert!(a.shr_round(sh).contains((x + (1i64 << (sh - 1))) >> sh));
            }
            let f = QFormat::new(g.usize(2, 16) as u32, 0);
            assert!(a.clamp_to(f).contains(f.saturate(x)));
        });
    }

    #[test]
    fn saturating_extremes_stay_ordered() {
        let huge = Interval::new(i128::MIN / 2, i128::MAX / 2);
        let s = huge.add(huge).scale(4);
        assert!(s.lo <= s.hi);
        assert_eq!(s.hi, i128::MAX);
        assert_eq!(s.lo, i128::MIN);
        assert_eq!(s.bits_needed(), 128);
    }
}
