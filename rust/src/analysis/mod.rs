//! Static bit-width prover for the multiplierless fixed-point datapath.
//!
//! Propagates worst-case value intervals through the frozen computation
//! graph of a calibrated [`crate::fixed::FixedPipeline`] — input
//! quantizer → MP band-pass banks → decimating low-pass chain → HWR
//! accumulators → kernel read-out → standardisation → MP inference
//! engine — using interval arithmetic over the actual trained
//! coefficient/weight magnitudes, and reports per stage how many bits
//! the worst case needs vs how many the hardware provisions.
//!
//! This derives the paper's Fig. 8 bit-width requirements by proof
//! instead of simulation: `certified()` means *no* input clip of the
//! given length can overflow a non-saturating register. Soundness of
//! the MP-stage transfer functions rests on the iterate/residual bounds
//! proven in [`crate::fixed::mp_int`] and is cross-checked empirically
//! by `tests/analysis_soundness.rs` against the checked-arithmetic
//! trace mode ([`crate::fixed::trace`]). See DESIGN.md §11.

pub mod graph;
pub mod interval;
pub mod report;

pub use graph::analyze;
pub use interval::Interval;
pub use report::{AnalysisReport, Provision, StageReport, StageStatus};
