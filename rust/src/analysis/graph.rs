//! The static dataflow walk over the fixed-point computation graph:
//! input quantizer → MP band-pass rows → decimating low-pass → HWR +
//! kernel accumulation → register read-out → standardisation → MP
//! inference → margins. Fig. 8's bit-width axis, derived by proof
//! instead of simulation.
//!
//! The walk mirrors [`crate::fixed::pipeline::FixedPipeline`] stage by
//! stage, using the *actual* quantised coefficients and trained weights
//! of the frozen pipeline (not just format ranges), and the proven
//! behaviour of the shift-Newton solver in [`crate::fixed::mp_int`]:
//!
//! * every MP operand row `r` built from taps `h` and a W-bit window
//!   `x` satisfies `r ∈ (H + X) ∪ -(H + X)` where `H` is the tap hull,
//! * the iterate starts at `z0 = min(r) - 1 - (gamma >> flog2 n)` and
//!   never exceeds `max(r)` (the shift step under-approximates the
//!   Newton step toward a root `<= max(r)`, and a forced +1 step stops
//!   at `ceil(root) <= max(r)`), so `z ∈ [R.lo - 1 - (gamma >> flog2 n),
//!   R.hi]` for the operand interval `R`,
//! * the residual is `sum(max(x - z, 0)) - gamma ∈ [-gamma,
//!   n * (R.hi - z.lo)]` at every point of the accumulation,
//! * a filter/head output differences two such iterates: `z+ - z-`.
//!
//! Each derivation step is a monotone interval transfer function from
//! [`crate::analysis::interval`], so the resulting per-stage intervals
//! are sound over-approximations of anything a concrete clip can
//! produce — DESIGN.md §11 gives the full argument, and
//! `tests/analysis_soundness.rs` checks dominance against traced runs.

use crate::analysis::interval::Interval;
use crate::analysis::report::{AnalysisReport, Provision, StageReport};
use crate::fixed::mp_int::flog2;
use crate::fixed::pipeline::FixedPipeline;
use crate::fixed::q::CsdScale;
use crate::fixed::trace;

/// Interval of the shift-Newton MP iterate for operand interval `r`
/// over `n` operands with margin `gamma`.
fn mp_z_interval(r: Interval, n: usize, gamma: i64) -> Interval {
    let gshift = i128::from(gamma >> flog2(n.max(1) as u32));
    Interval::new(
        r.lo.saturating_sub(1).saturating_sub(gshift),
        r.hi.max(r.lo), // hull is non-empty; z converges below max(r)
    )
}

/// Interval of the MP residual accumulator for operand interval `r`.
fn mp_resid_interval(r: Interval, z: Interval, n: usize, gamma: i64) -> Interval {
    let spread = r.hi.saturating_sub(z.lo).max(0);
    Interval::new(
        i128::from(gamma).saturating_neg().min(0),
        spread.saturating_mul(n as i128),
    )
}

/// Interval of the saturating CSD shift-add scaler applied to `x` —
/// mirrors [`CsdScale::apply`] term by term (each term is a monotone
/// shift of `x`; summing term intervals over-approximates the sum).
fn csd_interval(cs: &CsdScale, x: Interval) -> Interval {
    let mut acc = Interval::point(0);
    for &(sh, neg) in &cs.terms {
        let t = match sh.cmp(&0) {
            std::cmp::Ordering::Greater => x.shr_round(sh.unsigned_abs().min(126)),
            std::cmp::Ordering::Equal => x,
            std::cmp::Ordering::Less => x.shl(sh.unsigned_abs().min(63)),
        };
        acc = acc.add(if neg { t.neg() } else { t });
    }
    acc
}

/// One MP filter evaluation (band-pass or low-pass): returns the
/// `(row, z, resid, out)` intervals for taps hull `h` over signal
/// interval `sig`, with `n = 2 * taps` operands per MP call.
fn filter_intervals(
    h: Interval,
    sig: Interval,
    taps: usize,
    gamma: i64,
) -> (Interval, Interval, Interval, Interval) {
    let n = taps.saturating_mul(2);
    // rows are [h + x, -(h + x)] and [h - x, -(h - x)]: the hull of both
    // signs of both sums
    let s = h.add(sig).union(h.sub(sig));
    let row = s.union(s.neg());
    let z = mp_z_interval(row, n, gamma);
    let resid = mp_resid_interval(row, z, n, gamma);
    let out = z.sub(z); // z+ - z-, both in the z interval
    (row, z, resid, out)
}

/// Statically analyze a frozen pipeline processing clips of
/// `clip_len` samples, against the register budget `prov`.
pub fn analyze(pipe: &FixedPipeline, clip_len: usize, prov: &Provision) -> AnalysisReport {
    let dp = pipe.dp_fmt;
    let mut stages = Vec::new();

    // -- stage 1: input quantizer (clamping register write)
    let mut sig = Interval::of_format(dp);
    stages.push(StageReport::new(
        trace::INPUT.to_string(),
        sig,
        prov.w,
        true,
    ));

    // -- stages 2-3: per-octave MP filtering, HWR + accumulation
    let n_oct = pipe.plan.n_octaves;
    let bt = pipe.plan.bp_taps;
    let lt = pipe.plan.lp_taps;
    let gamma = pipe.gamma_f_q;
    let mut samples_at = clip_len as i128;
    let mut acc_int: Vec<Interval> = Vec::with_capacity(n_oct);
    for o in 0..n_oct {
        // band-pass bank: hull over the octave's actual quantised taps
        let mut h = Interval::point(0);
        for taps in &pipe.bp_q[o] {
            h = h.union(Interval::of_values(taps));
        }
        let (row, z, resid, out) = filter_intervals(h, sig, bt, gamma);
        let n = bt.saturating_mul(2);
        stages.push(StageReport::new(
            trace::bp_key(o, "row"),
            row,
            prov.mp_operand(),
            false,
        ));
        stages.push(StageReport::new(trace::bp_key(o, "z"), z, prov.mp_z(), false));
        stages.push(StageReport::new(
            trace::bp_key(o, "resid"),
            resid,
            prov.mp_resid(n),
            false,
        ));
        stages.push(StageReport::new(trace::bp_key(o, "out"), out, prov.w, true));
        // HWR + accumulate every sample of this octave's signal
        let acc = out.clamp_to(dp).hwr().scale(samples_at);
        stages.push(StageReport::new(
            trace::acc_key(o),
            acc,
            prov.acc_bits,
            false,
        ));
        acc_int.push(acc);
        // anti-alias low pass + decimate feeds the next octave
        if o.saturating_add(1) < n_oct {
            let hl = Interval::of_values(&pipe.lp_q[o]);
            let (row, z, resid, out) = filter_intervals(hl, sig, lt, gamma);
            let n = lt.saturating_mul(2);
            stages.push(StageReport::new(
                trace::lp_key(o, "row"),
                row,
                prov.mp_operand(),
                false,
            ));
            stages.push(StageReport::new(
                trace::lp_key(o, "z"),
                z,
                prov.mp_z(),
                false,
            ));
            stages.push(StageReport::new(
                trace::lp_key(o, "resid"),
                resid,
                prov.mp_resid(n),
                false,
            ));
            stages.push(StageReport::new(
                trace::lp_key(o, "out"),
                out,
                prov.w,
                true,
            ));
            sig = out.clamp_to(dp);
            samples_at = (samples_at.saturating_add(1)) / 2;
        }
    }

    // -- stages 4-5: kernel read-out, centring, CSD standardisation
    let f_per = pipe.plan.filters_per_octave.max(1);
    let mut readout: Option<Interval> = None;
    let mut centred: Option<Interval> = None;
    let mut feature: Option<Interval> = None;
    for (p, &sh) in pipe.acc_shift.iter().enumerate() {
        let o = (p / f_per).min(acc_int.len().saturating_sub(1));
        let pre = acc_int[o].shr_floor(sh);
        readout = Some(readout.map_or(pre, |r| r.union(pre)));
        let c = pre
            .clamp_to(dp)
            .sub(Interval::point(i128::from(pipe.mu_q[p])));
        centred = Some(centred.map_or(c, |r| r.union(c)));
        let f = csd_interval(&pipe.inv_sigma[p], c);
        feature = Some(feature.map_or(f, |r| r.union(f)));
    }
    let readout = readout.unwrap_or(Interval::point(0));
    let centred = centred.unwrap_or(Interval::point(0));
    let feature = feature.unwrap_or(Interval::point(0));
    stages.push(StageReport::new(
        trace::KERNEL_READOUT.to_string(),
        readout,
        prov.w,
        true,
    ));
    stages.push(StageReport::new(
        trace::STD_CENTRED.to_string(),
        centred,
        prov.centred(),
        false,
    ));
    stages.push(StageReport::new(
        trace::STD_FEATURE.to_string(),
        feature,
        prov.csd_internal(),
        true,
    ));

    // -- stage 6: MP inference engine over the standardised features
    if !pipe.wp_q.is_empty() {
        let k = feature.clamp_to(pipe.k_fmt);
        let n_bands = pipe.acc_shift.len();
        let n_inf = n_bands.saturating_mul(2).saturating_add(1);
        let mut row: Option<Interval> = None;
        for c in 0..pipe.wp_q.len() {
            let wp = Interval::of_values(&pipe.wp_q[c]);
            let wm = Interval::of_values(&pipe.wm_q[c]);
            // both the z+ row (wp + k, wm - k, bp) and z- row
            // (wp - k, wm + k, bm)
            let r = wp
                .add(k)
                .union(wp.sub(k))
                .union(wm.add(k))
                .union(wm.sub(k))
                .union(Interval::point(i128::from(pipe.bp_bias_q[c])))
                .union(Interval::point(i128::from(pipe.bm_bias_q[c])));
            row = Some(row.map_or(r, |x| x.union(r)));
        }
        let row = row.unwrap_or(Interval::point(0));
        let z = mp_z_interval(row, n_inf, pipe.gamma_1_q);
        let resid = mp_resid_interval(row, z, n_inf, pipe.gamma_1_q);
        let margin = z.sub(z);
        stages.push(StageReport::new(
            trace::inf_key("row"),
            row,
            prov.mp_operand(),
            false,
        ));
        stages.push(StageReport::new(trace::inf_key("z"), z, prov.mp_z(), false));
        stages.push(StageReport::new(
            trace::inf_key("resid"),
            resid,
            prov.mp_resid(n_inf),
            false,
        ));
        stages.push(StageReport::new(
            trace::inf_key("margin"),
            margin,
            prov.margin(),
            false,
        ));
    }

    AnalysisReport {
        bits: prov.w,
        acc_bits: prov.acc_bits,
        stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::multirate::BandPlan;
    use crate::fixed::pipeline::FixedConfig;
    use crate::mp::machine::{Params, Standardizer};

    fn dummy_pipe(bits: u32, n_octaves: usize) -> FixedPipeline {
        let mut plan = BandPlan::paper_default();
        plan.n_octaves = n_octaves;
        let nf = plan.n_filters();
        let params = Params {
            wp: vec![vec![0.5; nf], vec![-0.25; nf]],
            wm: vec![vec![-0.5; nf], vec![0.25; nf]],
            bp: vec![0.1, -0.1],
            bm: vec![-0.1, 0.1],
        };
        let std = Standardizer {
            mu: vec![10.0; nf],
            sigma: vec![5.0; nf],
        };
        let phi = vec![vec![50.0f32; nf]; 3];
        FixedPipeline::build(
            &plan,
            1.0,
            4.0,
            &params,
            &std,
            &phi,
            FixedConfig::with_bits(bits),
        )
    }

    #[test]
    fn paper_budget_is_certified() {
        let pipe = dummy_pipe(10, 6);
        let prov = Provision::for_pipeline(&pipe, 24);
        let rep = analyze(&pipe, 16_000, &prov);
        assert!(
            rep.certified(),
            "paper budget should certify:\n{}",
            rep.render()
        );
        // the kernel accumulator "just fits": 16000 * 511 < 2^23
        let acc0 = rep.stage("acc[0]").expect("acc[0] stage");
        assert_eq!(acc0.bits_needed, 24);
    }

    #[test]
    fn shrunk_accumulator_fails_the_gate() {
        let pipe = dummy_pipe(10, 6);
        let prov = Provision::for_pipeline(&pipe, 16);
        let rep = analyze(&pipe, 16_000, &prov);
        assert!(!rep.certified());
        assert!(rep
            .overflows()
            .iter()
            .any(|s| s.name.starts_with("acc[")));
    }

    #[test]
    fn stage_names_join_with_trace_keys() {
        let pipe = dummy_pipe(8, 3);
        let prov = Provision::for_pipeline(&pipe, 24);
        let rep = analyze(&pipe, 2048, &prov);
        for key in [
            crate::fixed::trace::INPUT.to_string(),
            crate::fixed::trace::bp_key(0, "row"),
            crate::fixed::trace::bp_key(2, "out"),
            crate::fixed::trace::lp_key(1, "z"),
            crate::fixed::trace::acc_key(2),
            crate::fixed::trace::KERNEL_READOUT.to_string(),
            crate::fixed::trace::STD_CENTRED.to_string(),
            crate::fixed::trace::STD_FEATURE.to_string(),
            crate::fixed::trace::inf_key("margin"),
        ] {
            assert!(rep.stage(&key).is_some(), "missing stage {key}");
        }
        // last octave has no low-pass stage
        assert!(rep.stage(&crate::fixed::trace::lp_key(2, "z")).is_none());
    }

    #[test]
    fn deeper_octaves_accumulate_less() {
        let pipe = dummy_pipe(10, 4);
        let prov = Provision::for_pipeline(&pipe, 24);
        let rep = analyze(&pipe, 16_000, &prov);
        let need = |o: usize| rep.stage(&crate::fixed::trace::acc_key(o)).unwrap().bits_needed;
        assert!(need(0) > need(3), "acc[0] {} vs acc[3] {}", need(0), need(3));
    }
}
