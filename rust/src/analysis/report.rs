//! Per-stage verdicts of the bit-width prover and the provisioned
//! register widths they are checked against.
//!
//! Every analyzed stage is either backed by a *saturating* register
//! (a [`crate::fixed::q::QFormat::saturate`] write — it clips, by
//! design, and can never wrap) or by plain binary arithmetic that
//! **would wrap silently** if the proven interval outgrew the register.
//! The CI gate therefore fails only on [`StageStatus::Overflow`] at a
//! non-saturating stage; a saturating stage whose pre-clamp interval
//! exceeds its width is reported as [`StageStatus::SaturatesByDesign`]
//! with the margin, which is exactly the "saturation risk" column an
//! FPGA designer reads off this table.

use crate::analysis::interval::Interval;
use crate::fixed::mp_int::clog2;
use crate::fixed::pipeline::FixedPipeline;

/// Verdict for one datapath stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageStatus {
    /// Required bits <= provisioned bits: the stage can never overflow.
    Proven,
    /// The pre-clamp interval exceeds the register, but the register
    /// write saturates: values clip (bounded error), they never wrap.
    SaturatesByDesign,
    /// The interval exceeds a register with wrap-around semantics:
    /// a silent-corruption hazard. Fails the CI gate.
    Overflow,
}

impl StageStatus {
    pub fn label(self) -> &'static str {
        match self {
            StageStatus::Proven => "proven",
            StageStatus::SaturatesByDesign => "sat-by-design",
            StageStatus::Overflow => "OVERFLOW",
        }
    }
}

/// One row of the report: the proven worst-case interval of a stage and
/// the width of the register that holds it.
#[derive(Clone, Debug)]
pub struct StageReport {
    /// Stage key, identical to the [`crate::fixed::trace`] key for the
    /// same site so the soundness harness can join the two.
    pub name: String,
    /// Proven worst-case interval (pre-clamp for saturating stages).
    pub interval: Interval,
    /// Minimal safe two's-complement width for `interval`.
    pub bits_needed: u32,
    /// Width actually provisioned for this stage.
    pub bits_provisioned: u32,
    /// Whether the stage's register write saturates (clips) rather than
    /// wraps.
    pub saturating: bool,
    pub status: StageStatus,
}

impl StageReport {
    pub fn new(
        name: String,
        interval: Interval,
        bits_provisioned: u32,
        saturating: bool,
    ) -> StageReport {
        let bits_needed = interval.bits_needed();
        let status = if bits_needed <= bits_provisioned {
            StageStatus::Proven
        } else if saturating {
            StageStatus::SaturatesByDesign
        } else {
            StageStatus::Overflow
        };
        StageReport {
            name,
            interval,
            bits_needed,
            bits_provisioned,
            saturating,
            status,
        }
    }
}

/// The provisioned register widths of the datapath, as functions of the
/// datapath width W — the same closed-form budgets
/// [`crate::fpga::resources`] prices and DESIGN.md derives:
///
/// * MP operand rows and the z register live on the W+2-bit subtract
///   datapath (row values reach +/-2^W when both addends sit at the
///   format rails, and z0 undershoots min(xs) by 1 + (gamma >> flog2 n)),
/// * the MP residual accumulator sums up to n operand-minus-z terms,
///   each < 2^(W+2), hence (W+1) + clog2(n) + 2 bits,
/// * a filter/head margin z+ - z- spans twice the z range: W+3 bits,
/// * the centred kernel subtract k_raw - mu needs W+1 bits,
/// * the CSD scaler's internal accumulator is budgeted at 2W bits and
///   saturates (see [`crate::fixed::q::CsdScale::apply`]).
#[derive(Clone, Copy, Debug)]
pub struct Provision {
    /// Datapath width W (samples, taps, filter outputs, features).
    pub w: u32,
    /// Kernel accumulator width (RegBank5/6; paper FPGA: 24).
    pub acc_bits: u32,
}

impl Provision {
    pub fn for_pipeline(pipe: &FixedPipeline, acc_bits: u32) -> Provision {
        Provision {
            w: pipe.cfg.bits,
            acc_bits,
        }
    }

    /// MP operand-row width (consumed by the x - z subtractor).
    pub fn mp_operand(&self) -> u32 {
        self.w.saturating_add(2)
    }

    /// MP z-register width.
    pub fn mp_z(&self) -> u32 {
        self.w.saturating_add(2)
    }

    /// MP residual-accumulator width for an n-operand evaluation.
    pub fn mp_resid(&self, n: usize) -> u32 {
        self.w
            .saturating_add(1)
            .saturating_add(clog2(n.max(1) as u32))
            .saturating_add(2)
    }

    /// Margin (z+ - z-) width.
    pub fn margin(&self) -> u32 {
        self.w.saturating_add(3)
    }

    /// Centred kernel subtract (k_raw - mu) width.
    pub fn centred(&self) -> u32 {
        self.w.saturating_add(1)
    }

    /// CSD scaler internal accumulator width (saturating).
    pub fn csd_internal(&self) -> u32 {
        self.w.saturating_mul(2)
    }
}

/// The full per-stage certification table for one pipeline build.
#[derive(Clone, Debug)]
pub struct AnalysisReport {
    /// Datapath width W the pipeline was built with.
    pub bits: u32,
    /// Provisioned kernel-accumulator width.
    pub acc_bits: u32,
    pub stages: Vec<StageReport>,
}

impl AnalysisReport {
    /// True iff no non-saturating stage can overflow: the configuration
    /// is statically certified.
    pub fn certified(&self) -> bool {
        !self
            .stages
            .iter()
            .any(|s| s.status == StageStatus::Overflow)
    }

    pub fn overflows(&self) -> Vec<&StageReport> {
        self.stages
            .iter()
            .filter(|s| s.status == StageStatus::Overflow)
            .collect()
    }

    /// Stage lookup by exact name.
    pub fn stage(&self, name: &str) -> Option<&StageReport> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// Worst (largest) bits_needed - bits_provisioned deficit over the
    /// non-saturating stages; negative means headroom everywhere.
    pub fn worst_deficit(&self) -> i64 {
        self.stages
            .iter()
            .filter(|s| !s.saturating)
            .map(|s| i64::from(s.bits_needed) - i64::from(s.bits_provisioned))
            .max()
            .unwrap_or(i64::MIN)
    }

    /// Plain-text table (fixed-width columns, one stage per row).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "static bit-width analysis: W = {} bits, accumulator = {} bits\n",
            self.bits, self.acc_bits
        ));
        out.push_str(&format!(
            "{:<18} {:>24} {:>6} {:>6}  {:<8} {}\n",
            "stage", "proven range", "need", "prov", "reg", "status"
        ));
        for s in &self.stages {
            out.push_str(&format!(
                "{:<18} {:>24} {:>6} {:>6}  {:<8} {}\n",
                s.name,
                format!("[{}, {}]", s.interval.lo, s.interval.hi),
                s.bits_needed,
                s.bits_provisioned,
                if s.saturating { "sat" } else { "wrap" },
                s.status.label()
            ));
        }
        let verdict = if self.certified() {
            "CERTIFIED: no non-saturating stage can overflow".to_string()
        } else {
            let names: Vec<&str> = self
                .overflows()
                .iter()
                .map(|s| s.name.as_str())
                .collect();
            format!("NOT CERTIFIED: possible overflow at {}", names.join(", "))
        };
        out.push_str(&verdict);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_assignment_rules() {
        let i = Interval::new(-2048, 2047); // needs 12 bits
        let ok = StageReport::new("a".into(), i, 12, false);
        assert_eq!(ok.status, StageStatus::Proven);
        let sat = StageReport::new("b".into(), i, 10, true);
        assert_eq!(sat.status, StageStatus::SaturatesByDesign);
        let bad = StageReport::new("c".into(), i, 11, false);
        assert_eq!(bad.status, StageStatus::Overflow);
    }

    #[test]
    fn certification_requires_no_wrap_overflow() {
        let i = Interval::new(0, 1023); // needs 11 bits
        let r = AnalysisReport {
            bits: 10,
            acc_bits: 24,
            stages: vec![
                StageReport::new("x".into(), i, 11, false),
                StageReport::new("y".into(), i, 4, true),
            ],
        };
        assert!(r.certified());
        assert!(r.render().contains("CERTIFIED"));
        let bad = AnalysisReport {
            bits: 10,
            acc_bits: 24,
            stages: vec![StageReport::new("x".into(), i, 10, false)],
        };
        assert!(!bad.certified());
        assert_eq!(bad.overflows().len(), 1);
        assert!(bad.render().contains("NOT CERTIFIED"));
        assert!(bad.worst_deficit() >= 1);
    }
}
