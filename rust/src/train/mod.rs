//! Training driver: MP-aware SGD with gamma annealing, executed entirely
//! through the AOT `mp_train_step_*` artifacts (jax.grad through the MP
//! custom_vjp — python authored the graph once; rust drives every step).
//!
//! Also defines [`TrainedModel`], the serialisable bundle (weights +
//! standardiser + gammas) the coordinator serves and the fixed-point
//! pipeline quantises.

use crate::mp::machine::{Params, Standardizer};
use crate::runtime::engine::ModelEngine;
use crate::util::json::Json;
use crate::util::prng::Pcg32;
use anyhow::{Context, Result};
use std::path::Path;

/// A trained, deployable model.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub classes: Vec<String>,
    pub params: Params,
    pub std: Standardizer,
    pub gamma_f: f32,
    pub gamma_1: f32,
}

impl TrainedModel {
    pub fn to_json(&self) -> Json {
        let rows = |m: &Vec<Vec<f32>>| {
            Json::Arr(m.iter().map(|r| Json::from_f32s(r)).collect())
        };
        Json::obj(vec![
            ("classes", Json::Arr(self.classes.iter().map(|c| Json::Str(c.clone())).collect())),
            ("wp", rows(&self.params.wp)),
            ("wm", rows(&self.params.wm)),
            ("bp", Json::from_f32s(&self.params.bp)),
            ("bm", Json::from_f32s(&self.params.bm)),
            ("mu", Json::from_f32s(&self.std.mu)),
            ("sigma", Json::from_f32s(&self.std.sigma)),
            ("gamma_f", Json::Num(f64::from(self.gamma_f))),
            ("gamma_1", Json::Num(f64::from(self.gamma_1))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TrainedModel> {
        let vecf = |j: &Json| -> Result<Vec<f32>> {
            Ok(j.as_arr()
                .context("expected array")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect())
        };
        let rows = |j: &Json| -> Result<Vec<Vec<f32>>> {
            j.as_arr()
                .context("expected array of rows")?
                .iter()
                .map(vecf)
                .collect()
        };
        Ok(TrainedModel {
            classes: j
                .get("classes")
                .as_arr()
                .context("classes")?
                .iter()
                .map(|c| c.as_str().unwrap_or("?").to_string())
                .collect(),
            params: Params {
                wp: rows(j.get("wp"))?,
                wm: rows(j.get("wm"))?,
                bp: vecf(j.get("bp"))?,
                bm: vecf(j.get("bm"))?,
            },
            std: Standardizer {
                mu: vecf(j.get("mu"))?,
                sigma: vecf(j.get("sigma"))?,
            },
            gamma_f: j.get("gamma_f").as_f64().context("gamma_f")? as f32,
            gamma_1: j.get("gamma_1").as_f64().context("gamma_1")? as f32,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainedModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        TrainedModel::from_json(&j)
    }
}

/// Hyper-parameters of the annealed SGD run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    /// gamma_1 annealing schedule: gamma(e) = end + (start-end)*decay^e
    pub gamma_start: f32,
    pub gamma_end: f32,
    pub gamma_decay: f32,
    pub seed: u64,
    pub init_scale: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 40,
            lr: 0.15,
            gamma_start: 10.0,
            gamma_end: 4.0,
            gamma_decay: 0.9,
            seed: 1,
            init_scale: 0.05,
        }
    }
}

/// Annealed gamma for epoch e.
pub fn gamma_at(cfg: &TrainConfig, epoch: usize) -> f32 {
    cfg.gamma_end + (cfg.gamma_start - cfg.gamma_end) * cfg.gamma_decay.powi(epoch as i32)
}

/// Train `heads`-way one-vs-all parameters on standardised features.
/// `targets[i]` has one {0,1} entry per head. Returns (params, per-step
/// loss curve). All steps run through the AOT train-step artifact.
pub fn train_heads(
    engine: &mut ModelEngine,
    k_rows: &[Vec<f32>],
    targets: &[Vec<f32>],
    heads: usize,
    cfg: &TrainConfig,
) -> Result<(Params, Vec<f32>)> {
    assert_eq!(k_rows.len(), targets.len());
    let p = engine.n_filters();
    let b = engine.rt.constants.train_batch;
    let mut rng = Pcg32::new(cfg.seed);
    let mut params = Params::zeros(heads, p);
    for row in params.wp.iter_mut().chain(params.wm.iter_mut()) {
        for w in row.iter_mut() {
            *w = cfg.init_scale * rng.normal() as f32;
        }
    }
    let mut order: Vec<usize> = (0..k_rows.len()).collect();
    let mut losses = Vec::new();
    for epoch in 0..cfg.epochs {
        let gamma = gamma_at(cfg, epoch);
        rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            // assemble a full batch (wrap around for the remainder)
            let mut k = Vec::with_capacity(b * p);
            let mut y = Vec::with_capacity(b * heads);
            for i in 0..b {
                let idx = chunk[i % chunk.len()];
                k.extend_from_slice(&k_rows[idx]);
                y.extend_from_slice(&targets[idx]);
            }
            let loss = engine.train_step(&mut params, &k, &y, cfg.lr, gamma)?;
            losses.push(loss);
        }
    }
    Ok((params, losses))
}

/// Multiclass convenience: fit the standardiser, build one-vs-all
/// targets from labels and train a `classes.len()`-head model.
pub fn train_model(
    engine: &mut ModelEngine,
    raw_phi: &[Vec<f32>],
    labels: &[usize],
    classes: &[String],
    gamma_f: f32,
    cfg: &TrainConfig,
) -> Result<(TrainedModel, Vec<f32>)> {
    let heads = classes.len();
    let std = Standardizer::fit(raw_phi);
    let k_rows = std.apply_all(raw_phi);
    let targets: Vec<Vec<f32>> = labels
        .iter()
        .map(|&l| (0..heads).map(|c| if c == l { 1.0 } else { 0.0 }).collect())
        .collect();
    let (params, losses) = train_heads(engine, &k_rows, &targets, heads, cfg)?;
    Ok((
        TrainedModel {
            classes: classes.to_vec(),
            params,
            std,
            gamma_f,
            gamma_1: cfg.gamma_end,
        },
        losses,
    ))
}

/// Multiclass accuracy (argmax over heads) via the batched eval artifact.
pub fn evaluate(
    engine: &mut ModelEngine,
    model: &TrainedModel,
    raw_phi: &[Vec<f32>],
    labels: &[usize],
) -> Result<f64> {
    let k_rows = model.std.apply_all(raw_phi);
    let margins = engine.eval_margins(&model.params, &k_rows, model.gamma_1)?;
    let correct = margins
        .iter()
        .zip(labels)
        .filter(|(m, &l)| {
            let pred = m
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            pred == l
        })
        .count();
    Ok(correct as f64 / labels.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_json_roundtrip() {
        let m = TrainedModel {
            classes: vec!["a".into(), "b".into()],
            params: Params {
                wp: vec![vec![0.5, -1.5], vec![0.0, 2.0]],
                wm: vec![vec![1.0, 0.0], vec![-0.25, 0.125]],
                bp: vec![0.1, 0.2],
                bm: vec![-0.1, -0.2],
            },
            std: Standardizer {
                mu: vec![10.0, 20.0],
                sigma: vec![1.0, 2.0],
            },
            gamma_f: 1.0,
            gamma_1: 4.0,
        };
        let j = m.to_json();
        let back = TrainedModel::from_json(&j).unwrap();
        assert_eq!(back.params, m.params);
        assert_eq!(back.classes, m.classes);
        assert_eq!(back.std.mu, m.std.mu);
    }

    #[test]
    fn model_save_load_file() {
        let m = TrainedModel {
            classes: vec!["x".into()],
            params: Params::zeros(1, 3),
            std: Standardizer {
                mu: vec![0.0; 3],
                sigma: vec![1.0; 3],
            },
            gamma_f: 0.5,
            gamma_1: 2.0,
        };
        let path = std::env::temp_dir().join("infilter_model_test.json");
        m.save(&path).unwrap();
        let back = TrainedModel::load(&path).unwrap();
        assert_eq!(back.params, m.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gamma_annealing_monotone_decreasing_to_end() {
        let cfg = TrainConfig::default();
        let g0 = gamma_at(&cfg, 0);
        let g5 = gamma_at(&cfg, 5);
        let g100 = gamma_at(&cfg, 100);
        assert!(g0 > g5 && g5 > g100);
        assert!((g100 - cfg.gamma_end).abs() < 1e-3);
        assert!((g0 - cfg.gamma_start).abs() < 1e-6);
    }

    #[test]
    fn e2e_training_on_artifacts_separates_classes() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let mut eng = ModelEngine::open(&dir, 1.0).unwrap();
        let p = eng.n_filters();
        let mut rng = Pcg32::new(9);
        // two synthetic feature clusters
        let mut phi = Vec::new();
        let mut labels = Vec::new();
        for i in 0..120 {
            let pos = i % 2 == 0;
            let row: Vec<f32> = (0..p)
                .map(|j| {
                    let base = if pos { 40.0 + j as f64 } else { 80.0 - j as f64 };
                    (base + 6.0 * rng.normal()) as f32
                })
                .collect();
            phi.push(row);
            labels.push(usize::from(!pos));
        }
        let classes = vec!["pos".to_string(), "neg".to_string()];
        let cfg = TrainConfig {
            epochs: 15,
            ..TrainConfig::default()
        };
        let (model, losses) = train_model(&mut eng, &phi, &labels, &classes, 1.0, &cfg).unwrap();
        assert!(losses.last().unwrap() < &losses[0]);
        let acc = evaluate(&mut eng, &model, &phi, &labels).unwrap();
        assert!(acc > 0.9, "train accuracy {acc}");
    }
}
