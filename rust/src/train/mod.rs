//! Training driver: MP-aware SGD with gamma annealing, executed entirely
//! through the AOT `mp_train_step_*` artifacts (jax.grad through the MP
//! custom_vjp — python authored the graph once; rust drives every step).
//!
//! Also defines [`TrainedModel`], the serialisable bundle (weights +
//! standardiser + gammas) the coordinator serves and the fixed-point
//! pipeline quantises.

use crate::mp::machine::{decide, Params, Standardizer};
use crate::mp::{mp, mp_grad};
use crate::runtime::engine::ModelEngine;
use crate::util::json::Json;
use crate::util::prng::Pcg32;
use anyhow::{Context, Result};
use std::path::Path;

/// A trained, deployable model.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub classes: Vec<String>,
    pub params: Params,
    pub std: Standardizer,
    pub gamma_f: f32,
    pub gamma_1: f32,
}

impl TrainedModel {
    /// Human-readable class name with a generic fallback: serving paths
    /// may see label/prediction indices beyond the trained head count
    /// (e.g. a loaded model that does not cover every synthetic event
    /// class), and must not panic rendering them.
    pub fn class_name(&self, idx: usize) -> String {
        self.classes
            .get(idx)
            .cloned()
            .unwrap_or_else(|| format!("class{idx}"))
    }

    /// Seeded random model of the right shape — not trained on
    /// anything. The shared fixture for coordinator/edge tests and the
    /// dispatch benches, which exercise serving mechanics (batching,
    /// sharding, routing) where only the shapes and determinism matter.
    pub fn synthetic(seed: u64, heads: usize, p: usize, mu: f32, sigma: f32) -> TrainedModel {
        let mut rng = Pcg32::new(seed);
        TrainedModel {
            classes: (0..heads).map(|c| format!("c{c}")).collect(),
            params: Params {
                wp: (0..heads).map(|_| rng.normal_vec(p)).collect(),
                wm: (0..heads).map(|_| rng.normal_vec(p)).collect(),
                bp: vec![0.0; heads],
                bm: vec![0.0; heads],
            },
            std: Standardizer {
                mu: vec![mu; p],
                sigma: vec![sigma; p],
            },
            gamma_f: 1.0,
            gamma_1: 4.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let rows = |m: &Vec<Vec<f32>>| {
            Json::Arr(m.iter().map(|r| Json::from_f32s(r)).collect())
        };
        Json::obj(vec![
            ("classes", Json::Arr(self.classes.iter().map(|c| Json::Str(c.clone())).collect())),
            ("wp", rows(&self.params.wp)),
            ("wm", rows(&self.params.wm)),
            ("bp", Json::from_f32s(&self.params.bp)),
            ("bm", Json::from_f32s(&self.params.bm)),
            ("mu", Json::from_f32s(&self.std.mu)),
            ("sigma", Json::from_f32s(&self.std.sigma)),
            ("gamma_f", Json::Num(f64::from(self.gamma_f))),
            ("gamma_1", Json::Num(f64::from(self.gamma_1))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TrainedModel> {
        let vecf = |j: &Json| -> Result<Vec<f32>> {
            Ok(j.as_arr()
                .context("expected array")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect())
        };
        let rows = |j: &Json| -> Result<Vec<Vec<f32>>> {
            j.as_arr()
                .context("expected array of rows")?
                .iter()
                .map(vecf)
                .collect()
        };
        Ok(TrainedModel {
            classes: j
                .get("classes")
                .as_arr()
                .context("classes")?
                .iter()
                .map(|c| c.as_str().unwrap_or("?").to_string())
                .collect(),
            params: Params {
                wp: rows(j.get("wp"))?,
                wm: rows(j.get("wm"))?,
                bp: vecf(j.get("bp"))?,
                bm: vecf(j.get("bm"))?,
            },
            std: Standardizer {
                mu: vecf(j.get("mu"))?,
                sigma: vecf(j.get("sigma"))?,
            },
            gamma_f: j.get("gamma_f").as_f64().context("gamma_f")? as f32,
            gamma_1: j.get("gamma_1").as_f64().context("gamma_1")? as f32,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainedModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        TrainedModel::from_json(&j)
    }

    /// Order-sensitive FNV-1a hash over the model's canonical byte
    /// serialisation (class names, weight/bias/standardiser f32 bits,
    /// gammas). Two processes holding bit-identical models — a gateway
    /// and the [`infilter-node`](crate::net) it connects to — agree on
    /// this value, so the wire handshake can reject a model mismatch
    /// before any frame is shipped.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(&(self.classes.len() as u64).to_le_bytes());
        for c in &self.classes {
            eat(&(c.len() as u64).to_le_bytes());
            eat(c.as_bytes());
        }
        for m in [&self.params.wp, &self.params.wm] {
            eat(&(m.len() as u64).to_le_bytes());
            for row in m {
                eat(&(row.len() as u64).to_le_bytes());
                for w in row {
                    eat(&w.to_bits().to_le_bytes());
                }
            }
        }
        for v in [&self.params.bp, &self.params.bm, &self.std.mu, &self.std.sigma] {
            eat(&(v.len() as u64).to_le_bytes());
            for w in v {
                eat(&w.to_bits().to_le_bytes());
            }
        }
        eat(&self.gamma_f.to_bits().to_le_bytes());
        eat(&self.gamma_1.to_bits().to_le_bytes());
        h
    }
}

/// Deterministic quick model trained entirely on the CPU backend (paper
/// clip geometry, small synthetic ESC-10 subset): the default on-node
/// model for `edge-fleet` and the `infilter-node` / `serve --connect`
/// pair. Training is bit-deterministic in `seed`/`scale`/`epochs` (the
/// parallel feature extraction is order-preserving and per-clip
/// independent), so a gateway and a node that run this with the same
/// arguments hold identical models and identical
/// [`TrainedModel::fingerprint`]s without sharing a file.
pub fn quick_cpu_model(
    seed: u64,
    scale: f64,
    epochs: usize,
    gamma_f: f32,
    threads: usize,
) -> TrainedModel {
    quick_cpu_model_with_phi(seed, scale, epochs, gamma_f, threads).0
}

/// [`quick_cpu_model`] that also returns the raw (unstandardised)
/// training feature rows it extracted. The fixed-point calibrator
/// ([`crate::fixed::FixedPipeline::build`]) and the `analyze` bit-width
/// prover need these rows to size accumulator shifts and Q formats, and
/// re-extracting them would double the most expensive step of the quick
/// path.
pub fn quick_cpu_model_with_phi(
    seed: u64,
    scale: f64,
    epochs: usize,
    gamma_f: f32,
    threads: usize,
) -> (TrainedModel, Vec<Vec<f32>>) {
    let eng = crate::runtime::backend::CpuEngine::new(
        &crate::dsp::multirate::BandPlan::paper_default(),
        gamma_f,
    );
    let ds = crate::datasets::esc10::build(seed, scale);
    let clip_len = {
        use crate::runtime::backend::InferenceBackend;
        eng.frame_len() * eng.clip_frames()
    };
    let samps: Vec<&[f32]> = ds.train.iter().map(|c| &c.samples[..clip_len]).collect();
    let phi = eng.clip_features_many(&samps, threads);
    let labels: Vec<usize> = ds.train.iter().map(|c| c.label).collect();
    let tc = TrainConfig {
        epochs,
        seed,
        ..TrainConfig::default()
    };
    let (model, losses) = train_model_cpu(&phi, &labels, &ds.classes, gamma_f, &tc);
    let acc = evaluate_cpu(&model, &phi, &labels);
    crate::log_info!(
        "quick CPU model (seed {seed}, scale {scale}): train accuracy {:.1}% \
         (loss {:.4} -> {:.4}, fingerprint {:016x})",
        100.0 * acc,
        losses.first().copied().unwrap_or(0.0),
        losses.last().copied().unwrap_or(0.0),
        model.fingerprint()
    );
    (model, phi)
}

/// Hyper-parameters of the annealed SGD run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    /// gamma_1 annealing schedule: gamma(e) = end + (start-end)*decay^e
    pub gamma_start: f32,
    pub gamma_end: f32,
    pub gamma_decay: f32,
    pub seed: u64,
    pub init_scale: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 40,
            lr: 0.15,
            gamma_start: 10.0,
            gamma_end: 4.0,
            gamma_decay: 0.9,
            seed: 1,
            init_scale: 0.05,
        }
    }
}

/// Annealed gamma for epoch e.
pub fn gamma_at(cfg: &TrainConfig, epoch: usize) -> f32 {
    cfg.gamma_end + (cfg.gamma_start - cfg.gamma_end) * cfg.gamma_decay.powi(epoch as i32)
}

/// Train `heads`-way one-vs-all parameters on standardised features.
/// `targets[i]` has one {0,1} entry per head. Returns (params, per-step
/// loss curve). All steps run through the AOT train-step artifact.
pub fn train_heads(
    engine: &mut ModelEngine,
    k_rows: &[Vec<f32>],
    targets: &[Vec<f32>],
    heads: usize,
    cfg: &TrainConfig,
) -> Result<(Params, Vec<f32>)> {
    assert_eq!(k_rows.len(), targets.len());
    let p = engine.n_filters();
    let b = engine.rt.constants.train_batch;
    let mut rng = Pcg32::new(cfg.seed);
    let mut params = Params::zeros(heads, p);
    for row in params.wp.iter_mut().chain(params.wm.iter_mut()) {
        for w in row.iter_mut() {
            *w = cfg.init_scale * rng.normal() as f32;
        }
    }
    let mut order: Vec<usize> = (0..k_rows.len()).collect();
    let mut losses = Vec::new();
    for epoch in 0..cfg.epochs {
        let gamma = gamma_at(cfg, epoch);
        rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            // assemble a full batch (wrap around for the remainder)
            let mut k = Vec::with_capacity(b * p);
            let mut y = Vec::with_capacity(b * heads);
            for i in 0..b {
                let idx = chunk[i % chunk.len()];
                k.extend_from_slice(&k_rows[idx]);
                y.extend_from_slice(&targets[idx]);
            }
            let loss = engine.train_step(&mut params, &k, &y, cfg.lr, gamma)?;
            losses.push(loss);
        }
    }
    Ok((params, losses))
}

/// Multiclass convenience: fit the standardiser, build one-vs-all
/// targets from labels and train a `classes.len()`-head model.
pub fn train_model(
    engine: &mut ModelEngine,
    raw_phi: &[Vec<f32>],
    labels: &[usize],
    classes: &[String],
    gamma_f: f32,
    cfg: &TrainConfig,
) -> Result<(TrainedModel, Vec<f32>)> {
    let heads = classes.len();
    let std = Standardizer::fit(raw_phi);
    let k_rows = std.apply_all(raw_phi);
    let targets: Vec<Vec<f32>> = labels
        .iter()
        .map(|&l| (0..heads).map(|c| if c == l { 1.0 } else { 0.0 }).collect())
        .collect();
    let (params, losses) = train_heads(engine, &k_rows, &targets, heads, cfg)?;
    Ok((
        TrainedModel {
            classes: classes.to_vec(),
            params,
            std,
            gamma_f,
            gamma_1: cfg.gamma_end,
        },
        losses,
    ))
}

/// One SGD step on head `c` for one sample (`k` standardised features,
/// target `t` in {-1, +1}); returns the sample's squared loss. The
/// sub-gradients flow through both MP evaluations (eqs. 3-4), the
/// normalisation MP (eq. 5) and the rectified difference (eqs. 6-7),
/// using the analytic [`mp_grad`].
fn sgd_step_head(params: &mut Params, c: usize, k: &[f32], t: f32, gamma_1: f32, lr: f32) -> f32 {
    let p_len = k.len();
    let mut a = Vec::with_capacity(2 * p_len + 1);
    let mut b = Vec::with_capacity(2 * p_len + 1);
    for i in 0..p_len {
        a.push(params.wp[c][i] + k[i]);
        b.push(params.wp[c][i] - k[i]);
    }
    for i in 0..p_len {
        a.push(params.wm[c][i] - k[i]);
        b.push(params.wm[c][i] + k[i]);
    }
    a.push(params.bp[c]);
    b.push(params.bm[c]);
    let z_plus = mp(&a, gamma_1);
    let z_minus = mp(&b, gamma_1);
    let (ga, _) = mp_grad(&a, gamma_1);
    let (gb, _) = mp_grad(&b, gamma_1);
    // normalisation (eq. 5, gamma_n = 1) and its gradient
    let pair = [z_plus, z_minus];
    let z = mp(&pair, 1.0);
    let (h, _) = mp_grad(&pair, 1.0);
    let pp = (z_plus - z).max(0.0);
    let pm = (z_minus - z).max(0.0);
    let p_val = pp - pm;
    let u = f32::from(u8::from(z_plus > z));
    let v = f32::from(u8::from(z_minus > z));
    let dp_dzp = u * (1.0 - h[0]) + v * h[0];
    let dp_dzm = -u * h[1] - v * (1.0 - h[1]);
    let g = 2.0 * (p_val - t);
    let gp = g * dp_dzp;
    let gm = g * dp_dzm;
    for i in 0..p_len {
        params.wp[c][i] -= lr * (gp * ga[i] + gm * gb[i]);
        params.wm[c][i] -= lr * (gp * ga[p_len + i] + gm * gb[p_len + i]);
    }
    params.bp[c] -= lr * gp * ga[2 * p_len];
    params.bm[c] -= lr * gm * gb[2 * p_len];
    (p_val - t) * (p_val - t)
}

/// Multiclass training entirely on the CPU: per-sample SGD through the
/// float MP machine with analytic sub-gradients — the no-PJRT mirror of
/// [`train_model`], used by the edge fleet and any artifact-free build.
/// Returns the model plus the per-epoch mean loss curve.
pub fn train_model_cpu(
    raw_phi: &[Vec<f32>],
    labels: &[usize],
    classes: &[String],
    gamma_f: f32,
    cfg: &TrainConfig,
) -> (TrainedModel, Vec<f32>) {
    assert_eq!(raw_phi.len(), labels.len());
    let heads = classes.len();
    let p = raw_phi.first().map_or(0, Vec::len);
    let std = Standardizer::fit(raw_phi);
    let k_rows = std.apply_all(raw_phi);
    let mut rng = Pcg32::new(cfg.seed);
    let mut params = Params::zeros(heads, p);
    for row in params.wp.iter_mut().chain(params.wm.iter_mut()) {
        for w in row.iter_mut() {
            *w = cfg.init_scale * rng.normal() as f32;
        }
    }
    let mut order: Vec<usize> = (0..k_rows.len()).collect();
    let mut losses = Vec::with_capacity(cfg.epochs);
    for epoch in 0..cfg.epochs {
        let gamma = gamma_at(cfg, epoch);
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut n = 0usize;
        for &idx in &order {
            for c in 0..heads {
                let t = if labels[idx] == c { 1.0 } else { -1.0 };
                let l = sgd_step_head(&mut params, c, &k_rows[idx], t, gamma, cfg.lr);
                loss_sum += f64::from(l);
                n += 1;
            }
        }
        losses.push((loss_sum / n.max(1) as f64) as f32);
    }
    (
        TrainedModel {
            classes: classes.to_vec(),
            params,
            std,
            gamma_f,
            gamma_1: cfg.gamma_end,
        },
        losses,
    )
}

/// Multiclass accuracy via the rust MP machine (no artifacts needed).
pub fn evaluate_cpu(model: &TrainedModel, raw_phi: &[Vec<f32>], labels: &[usize]) -> f64 {
    let mut correct = 0usize;
    for (phi, &l) in raw_phi.iter().zip(labels) {
        let k = model.std.apply(phi);
        let ds = decide(&model.params, &k, model.gamma_1);
        let pred = ds
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.p.partial_cmp(&y.1.p).unwrap())
            .map_or(0, |(i, _)| i);
        if pred == l {
            correct += 1;
        }
    }
    correct as f64 / labels.len().max(1) as f64
}

/// Multiclass accuracy (argmax over heads) via the batched eval artifact.
pub fn evaluate(
    engine: &mut ModelEngine,
    model: &TrainedModel,
    raw_phi: &[Vec<f32>],
    labels: &[usize],
) -> Result<f64> {
    let k_rows = model.std.apply_all(raw_phi);
    let margins = engine.eval_margins(&model.params, &k_rows, model.gamma_1)?;
    let correct = margins
        .iter()
        .zip(labels)
        .filter(|(m, &l)| crate::util::stats::argmax(m) == l)
        .count();
    Ok(correct as f64 / labels.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_json_roundtrip() {
        let m = TrainedModel {
            classes: vec!["a".into(), "b".into()],
            params: Params {
                wp: vec![vec![0.5, -1.5], vec![0.0, 2.0]],
                wm: vec![vec![1.0, 0.0], vec![-0.25, 0.125]],
                bp: vec![0.1, 0.2],
                bm: vec![-0.1, -0.2],
            },
            std: Standardizer {
                mu: vec![10.0, 20.0],
                sigma: vec![1.0, 2.0],
            },
            gamma_f: 1.0,
            gamma_1: 4.0,
        };
        let j = m.to_json();
        let back = TrainedModel::from_json(&j).unwrap();
        assert_eq!(back.params, m.params);
        assert_eq!(back.classes, m.classes);
        assert_eq!(back.std.mu, m.std.mu);
    }

    #[test]
    fn fingerprint_is_stable_and_weight_sensitive() {
        let m = TrainedModel::synthetic(9, 3, 4, 5.0, 2.0);
        let same = TrainedModel::synthetic(9, 3, 4, 5.0, 2.0);
        assert_eq!(m.fingerprint(), same.fingerprint());
        // a single-bit weight change must move the fingerprint
        let mut tweaked = m.clone();
        tweaked.params.wp[0][0] += 1e-6;
        assert_ne!(m.fingerprint(), tweaked.fingerprint());
        // so must a renamed class and a different gamma
        let mut renamed = m.clone();
        renamed.classes[0] = "other".into();
        assert_ne!(m.fingerprint(), renamed.fingerprint());
        let mut regamma = m.clone();
        regamma.gamma_1 += 0.5;
        assert_ne!(m.fingerprint(), regamma.fingerprint());
        // the json save/load roundtrip preserves it (exact f32 emission)
        let path = std::env::temp_dir().join("infilter_fp_roundtrip.json");
        m.save(&path).unwrap();
        let back = TrainedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(m.fingerprint(), back.fingerprint());
    }

    #[test]
    fn model_save_load_file() {
        let m = TrainedModel {
            classes: vec!["x".into()],
            params: Params::zeros(1, 3),
            std: Standardizer {
                mu: vec![0.0; 3],
                sigma: vec![1.0; 3],
            },
            gamma_f: 0.5,
            gamma_1: 2.0,
        };
        let path = std::env::temp_dir().join("infilter_model_test.json");
        m.save(&path).unwrap();
        let back = TrainedModel::load(&path).unwrap();
        assert_eq!(back.params, m.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gamma_annealing_monotone_decreasing_to_end() {
        let cfg = TrainConfig::default();
        let g0 = gamma_at(&cfg, 0);
        let g5 = gamma_at(&cfg, 5);
        let g100 = gamma_at(&cfg, 100);
        assert!(g0 > g5 && g5 > g100);
        assert!((g100 - cfg.gamma_end).abs() < 1e-3);
        assert!((g0 - cfg.gamma_start).abs() < 1e-6);
    }

    #[test]
    fn cpu_training_separates_toy_clusters() {
        let mut rng = Pcg32::new(9);
        let p = 12;
        let mut phi = Vec::new();
        let mut labels = Vec::new();
        for i in 0..80 {
            let pos = i % 2 == 0;
            let row: Vec<f32> = (0..p)
                .map(|j| {
                    let base = if pos { 40.0 + j as f64 } else { 80.0 - j as f64 };
                    (base + 6.0 * rng.normal()) as f32
                })
                .collect();
            phi.push(row);
            labels.push(usize::from(!pos));
        }
        let classes = vec!["pos".to_string(), "neg".to_string()];
        let cfg = TrainConfig {
            epochs: 40,
            lr: 0.3,
            seed: 4,
            ..TrainConfig::default()
        };
        let (model, losses) = train_model_cpu(&phi, &labels, &classes, 1.0, &cfg);
        assert!(losses.last().unwrap() < &losses[0], "losses {losses:?}");
        let acc = evaluate_cpu(&model, &phi, &labels);
        assert!(acc > 0.7, "cpu train accuracy {acc}");
    }

    #[test]
    fn cpu_step_forward_pass_matches_decide_head() {
        // the trainer re-assembles the eq. 3-7 operands; pin its forward
        // pass to the inference path so the two can never drift apart
        let mut rng = Pcg32::new(33);
        let p = 10;
        let mut params = Params::zeros(3, p);
        for row in params.wp.iter_mut().chain(params.wm.iter_mut()) {
            for w in row.iter_mut() {
                *w = rng.normal() as f32;
            }
        }
        params.bp = rng.normal_vec(3);
        params.bm = rng.normal_vec(3);
        let k = rng.normal_vec(p);
        for &gamma in &[2.0f32, 4.0, 8.0] {
            let ds = decide(&params, &k, gamma);
            for (c, d) in ds.iter().enumerate() {
                // lr = 0: pure forward pass, returns (p - t)^2
                let loss = sgd_step_head(&mut params, c, &k, 1.0, gamma, 0.0);
                let expect = (d.p - 1.0) * (d.p - 1.0);
                assert!(
                    (loss - expect).abs() < 1e-5,
                    "head {c} gamma {gamma}: loss {loss} expect {expect}"
                );
            }
        }
    }

    #[test]
    fn cpu_gradient_direction_reduces_single_sample_loss() {
        // one SGD step on one sample must not increase that sample's loss
        let mut rng = Pcg32::new(21);
        let p = 8;
        let mut params = Params::zeros(2, p);
        for row in params.wp.iter_mut().chain(params.wm.iter_mut()) {
            for w in row.iter_mut() {
                *w = 0.1 * rng.normal() as f32;
            }
        }
        let k: Vec<f32> = rng.normal_vec(p);
        for &t in &[1.0f32, -1.0] {
            let before = sgd_step_head(&mut params, 0, &k, t, 4.0, 0.05);
            let after = sgd_step_head(&mut params, 0, &k, t, 4.0, 0.0);
            assert!(
                after <= before + 1e-5,
                "loss went {before} -> {after} for target {t}"
            );
        }
    }

    #[test]
    fn e2e_training_on_artifacts_separates_classes() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let mut eng = ModelEngine::open(&dir, 1.0).unwrap();
        let p = eng.n_filters();
        let mut rng = Pcg32::new(9);
        // two synthetic feature clusters
        let mut phi = Vec::new();
        let mut labels = Vec::new();
        for i in 0..120 {
            let pos = i % 2 == 0;
            let row: Vec<f32> = (0..p)
                .map(|j| {
                    let base = if pos { 40.0 + j as f64 } else { 80.0 - j as f64 };
                    (base + 6.0 * rng.normal()) as f32
                })
                .collect();
            phi.push(row);
            labels.push(usize::from(!pos));
        }
        let classes = vec!["pos".to_string(), "neg".to_string()];
        let cfg = TrainConfig {
            epochs: 15,
            ..TrainConfig::default()
        };
        let (model, losses) = train_model(&mut eng, &phi, &labels, &classes, 1.0, &cfg).unwrap();
        assert!(losses.last().unwrap() < &losses[0]);
        let acc = evaluate(&mut eng, &model, &phi, &labels).unwrap();
        assert!(acc > 0.9, "train accuracy {acc}");
    }
}
