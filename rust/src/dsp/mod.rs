//! Signal-processing substrate: FIR design (windowed sinc), the Greenwood
//! cochlear map, the paper's multirate octave band plan, and test signals.

pub mod chirp;
pub mod fir;
pub mod greenwood;
pub mod multirate;
pub mod window;
