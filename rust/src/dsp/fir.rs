//! FIR filter design (windowed sinc) and direct-form streaming filtering.
//!
//! These are the *conventional* (multiply-accumulate) filters: the float
//! baseline of the paper's Fig. 4 and the "floating point" columns of
//! Tables III/IV. The multiplierless MP versions of the same filters live
//! in `crate::mp` (float semantics) and `crate::fixed` (hardware model).

use super::window::Window;
use std::f64::consts::PI;

fn sinc(x: f64) -> f64 {
    if x.abs() < 1e-12 {
        1.0
    } else {
        (PI * x).sin() / (PI * x)
    }
}

/// Windowed-sinc low pass. `fc` is the cutoff in cycles/sample (0, 0.5);
/// DC gain is normalised to exactly 1.
pub fn lowpass(fc: f64, taps: usize, window: Window) -> Vec<f64> {
    assert!(fc > 0.0 && fc < 0.5, "fc = {fc} out of (0, 0.5)");
    assert!(taps >= 2);
    let w = window.coeffs(taps);
    let c = (taps - 1) as f64 / 2.0;
    let mut h: Vec<f64> = (0..taps)
        .map(|k| 2.0 * fc * sinc(2.0 * fc * (k as f64 - c)) * w[k])
        .collect();
    let dc: f64 = h.iter().sum();
    for x in &mut h {
        *x /= dc;
    }
    h
}

/// Windowed-sinc band pass for the band [f1, f2] (cycles/sample).
/// Peak gain at the centre frequency is normalised to 1.
pub fn bandpass(f1: f64, f2: f64, taps: usize, window: Window) -> Vec<f64> {
    assert!(f1 > 0.0 && f2 < 0.5 && f1 < f2, "bad band [{f1}, {f2}]");
    let w = window.coeffs(taps);
    let c = (taps - 1) as f64 / 2.0;
    let mut h: Vec<f64> = (0..taps)
        .map(|k| {
            let t = k as f64 - c;
            (2.0 * f2 * sinc(2.0 * f2 * t) - 2.0 * f1 * sinc(2.0 * f1 * t)) * w[k]
        })
        .collect();
    let fc = 0.5 * (f1 + f2);
    let gain = magnitude_at(&h, fc).max(1e-12);
    for x in &mut h {
        *x /= gain;
    }
    h
}

/// Largest coefficient magnitude — the quantity fixed-point calibration
/// and the static bit-width analyzer size coefficient formats from.
pub fn max_abs(h: &[f64]) -> f64 {
    h.iter().fold(0.0f64, |a, &b| a.max(b.abs()))
}

/// L1 norm of the taps — the classical worst-case FIR output bound
/// (|y| <= ||h||_1 * max|x|), quoted in the analyzer report docs as the
/// conventional-datapath analogue of the MP interval bound.
pub fn l1_norm(h: &[f64]) -> f64 {
    h.iter().fold(0.0f64, |a, &b| a + b.abs())
}

/// |H(f)| at frequency f (cycles/sample) by direct evaluation.
pub fn magnitude_at(h: &[f64], f: f64) -> f64 {
    let (mut re, mut im) = (0.0f64, 0.0f64);
    for (k, &hk) in h.iter().enumerate() {
        let ang = -2.0 * PI * f * k as f64;
        re += hk * ang.cos();
        im += hk * ang.sin();
    }
    (re * re + im * im).sqrt()
}

/// Magnitude response sampled at `n` frequencies in (0, 0.5).
pub fn magnitude_response(h: &[f64], n: usize) -> Vec<(f64, f64)> {
    (1..=n)
        .map(|i| {
            let f = 0.5 * i as f64 / (n + 1) as f64;
            (f, magnitude_at(h, f))
        })
        .collect()
}

/// Direct-form streaming FIR with an explicit delay line — the float
/// counterpart of the HLO frame-features state carry, used by Fig 4 and
/// the conventional feature extractor.
#[derive(Clone, Debug)]
pub struct FirFilter {
    h: Vec<f64>,
    /// delay[0] = x[n-1], delay[1] = x[n-2], ...
    delay: Vec<f64>,
}

impl FirFilter {
    pub fn new(h: Vec<f64>) -> FirFilter {
        let n = h.len();
        FirFilter {
            h,
            delay: vec![0.0; n.saturating_sub(1)],
        }
    }

    pub fn taps(&self) -> usize {
        self.h.len()
    }

    pub fn reset(&mut self) {
        self.delay.iter_mut().for_each(|d| *d = 0.0);
    }

    /// One sample in, one sample out.
    pub fn step(&mut self, x: f64) -> f64 {
        let mut acc = self.h[0] * x;
        for (k, &d) in self.delay.iter().enumerate() {
            acc += self.h[k + 1] * d;
        }
        // shift the delay line (newest first)
        for k in (1..self.delay.len()).rev() {
            self.delay[k] = self.delay[k - 1];
        }
        if !self.delay.is_empty() {
            self.delay[0] = x;
        }
        acc
    }

    /// Filter a whole block (streaming: state persists across calls).
    pub fn process(&mut self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.step(f64::from(x)) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn lowpass_dc_gain_one_and_stopband() {
        let h = lowpass(0.1, 63, Window::Hamming);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(magnitude_at(&h, 0.001) > 0.99);
        assert!(magnitude_at(&h, 0.3) < 0.01, "stopband leak");
    }

    #[test]
    fn bandpass_center_gain_one_and_rejection() {
        let h = bandpass(0.1, 0.2, 101, Window::Hamming);
        assert!((magnitude_at(&h, 0.15) - 1.0).abs() < 1e-9);
        assert!(magnitude_at(&h, 0.01) < 0.01);
        assert!(magnitude_at(&h, 0.45) < 0.01);
    }

    #[test]
    fn bandpass_low_order_still_selective() {
        // the paper's order-15 (16-tap) band filters: passband > stopband
        let h = bandpass(0.25, 0.3, 16, Window::Hamming);
        let pass = magnitude_at(&h, 0.275);
        let stop = magnitude_at(&h, 0.05);
        assert!(pass > 3.0 * stop, "pass {pass} stop {stop}");
    }

    #[test]
    fn max_abs_and_l1_norm() {
        let h = [0.5, -0.75, 0.25];
        assert!((max_abs(&h) - 0.75).abs() < 1e-15);
        assert!((l1_norm(&h) - 1.5).abs() < 1e-15);
        assert_eq!(max_abs(&[]), 0.0);
        assert_eq!(l1_norm(&[]), 0.0);
        // l1 always dominates max
        let g = lowpass(0.12, 33, Window::Hamming);
        assert!(l1_norm(&g) >= max_abs(&g));
    }

    #[test]
    fn fir_filter_impulse_response_is_h() {
        let h = vec![0.5, -0.25, 0.125];
        let mut f = FirFilter::new(h.clone());
        let mut x = vec![1.0f32, 0.0, 0.0, 0.0];
        let y = f.process(&mut x);
        for (k, &hk) in h.iter().enumerate() {
            assert!((f64::from(y[k]) - hk).abs() < 1e-6);
        }
        assert!(f64::from(y[3]).abs() < 1e-9);
    }

    #[test]
    fn fir_streaming_equals_batch() {
        check("fir-streaming", 25, |g| {
            let taps = g.usize(2, 12);
            let t = g.usize(8, 64);
            let h: Vec<f64> = (0..taps).map(|_| g.f64(-1.0, 1.0)).collect();
            let xs: Vec<f32> = g.signal(t, 1.0);
            let mut whole = FirFilter::new(h.clone());
            let yw = whole.process(&xs);
            let mut chunked = FirFilter::new(h);
            let mut yc = Vec::new();
            let mid = t / 2;
            yc.extend(chunked.process(&xs[..mid]));
            yc.extend(chunked.process(&xs[mid..]));
            for (a, b) in yw.iter().zip(&yc) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn fir_linearity() {
        check("fir-linearity", 15, |g| {
            let h: Vec<f64> = (0..8).map(|_| g.f64(-1.0, 1.0)).collect();
            let xs = g.signal(32, 1.0);
            let a = g.f32(0.5, 2.0);
            let mut f1 = FirFilter::new(h.clone());
            let mut f2 = FirFilter::new(h);
            let y1 = f1.process(&xs);
            let scaled: Vec<f32> = xs.iter().map(|&x| a * x).collect();
            let y2 = f2.process(&scaled);
            for (p, q) in y1.iter().zip(&y2) {
                assert!((a * p - q).abs() < 1e-3, "{} vs {}", a * p, q);
            }
        });
    }

    #[test]
    fn tone_through_bandpass() {
        // a tone inside the band passes, outside is attenuated
        let h = bandpass(0.1, 0.2, 64, Window::Hamming);
        let tone = |f: f64| -> f64 {
            let mut filt = FirFilter::new(h.clone());
            let xs: Vec<f32> = (0..512)
                .map(|n| (2.0 * PI * f * n as f64).sin() as f32)
                .collect();
            let ys = filt.process(&xs);
            ys[128..]
                .iter()
                .map(|&y| f64::from(y) * f64::from(y))
                .sum::<f64>()
                .sqrt()
        };
        assert!(tone(0.15) > 5.0 * tone(0.35));
    }
}
