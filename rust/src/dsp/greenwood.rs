//! Greenwood cochlear frequency-position function [45].
//!
//! f(x) = A (10^(a x) - k) maps normalised cochlear place x in [0, 1]
//! (apex -> base) to frequency. The paper spaces its filter-bank centre
//! frequencies on this map ("resonators with center frequencies based on
//! the Greenwood function").

/// Human cochlea constants (Greenwood 1990).
pub const A: f64 = 165.4;
pub const ALPHA: f64 = 2.1;
pub const K: f64 = 0.88;

/// Frequency (Hz) at normalised place x in [0, 1].
pub fn place_to_freq(x: f64) -> f64 {
    A * (10f64.powf(ALPHA * x) - K)
}

/// Inverse map: normalised place for frequency f (Hz).
pub fn freq_to_place(f: f64) -> f64 {
    ((f / A + K).log10()) / ALPHA
}

/// `n` centre frequencies Greenwood-spaced (uniform on the place axis)
/// between f_lo and f_hi inclusive, ascending.
pub fn centers(n: usize, f_lo: f64, f_hi: f64) -> Vec<f64> {
    assert!(n >= 1 && f_lo > 0.0 && f_hi > f_lo);
    let x_lo = freq_to_place(f_lo);
    let x_hi = freq_to_place(f_hi);
    (0..n)
        .map(|i| {
            let t = if n == 1 {
                0.5
            } else {
                i as f64 / (n - 1) as f64
            };
            place_to_freq(x_lo + t * (x_hi - x_lo))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for f in [100.0, 440.0, 1000.0, 4000.0, 7800.0] {
            let x = freq_to_place(f);
            assert!((place_to_freq(x) - f).abs() / f < 1e-10);
        }
    }

    #[test]
    fn monotone_increasing() {
        let cs = centers(30, 125.0, 7800.0);
        assert_eq!(cs.len(), 30);
        for w in cs.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!((cs[0] - 125.0).abs() < 1e-6);
        assert!((cs[29] - 7800.0).abs() < 1e-6);
    }

    #[test]
    fn denser_at_low_frequencies() {
        // Greenwood spacing is roughly log: low-frequency gaps are smaller
        let cs = centers(10, 125.0, 7800.0);
        assert!(cs[1] - cs[0] < cs[9] - cs[8]);
    }

    #[test]
    fn known_values() {
        // x = 0 -> A (1 - k) = 165.4 * 0.12 = 19.85 Hz (cochlear apex)
        assert!((place_to_freq(0.0) - 19.848).abs() < 1e-2);
        // x = 1 -> ~20.7 kHz (base)
        let base = place_to_freq(1.0);
        assert!(base > 20_000.0 && base < 21_000.0, "{base}");
    }
}
