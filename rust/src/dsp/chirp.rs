//! Test signals: linear chirps and tones (paper Figs. 4 and 6 use a
//! chirp with increasing frequency sampled at 16 kHz).

use std::f64::consts::PI;

/// Linear chirp from f0 to f1 Hz over n samples at `fs` Hz, amplitude 1.
pub fn linear_chirp(f0: f64, f1: f64, n: usize, fs: f64) -> Vec<f32> {
    let dur = n as f64 / fs;
    let k = (f1 - f0) / dur;
    (0..n)
        .map(|i| {
            let t = i as f64 / fs;
            (2.0 * PI * (f0 * t + 0.5 * k * t * t)).sin() as f32
        })
        .collect()
}

/// Instantaneous frequency of the same chirp at sample i.
pub fn chirp_freq_at(f0: f64, f1: f64, n: usize, fs: f64, i: usize) -> f64 {
    let dur = n as f64 / fs;
    let k = (f1 - f0) / dur;
    f0 + k * (i as f64 / fs)
}

/// Pure tone at f Hz.
pub fn tone(f: f64, n: usize, fs: f64, amplitude: f64) -> Vec<f32> {
    (0..n)
        .map(|i| (amplitude * (2.0 * PI * f * i as f64 / fs).sin()) as f32)
        .collect()
}

/// Sliding-window RMS envelope with window w (output length == input).
pub fn rms_envelope(xs: &[f32], w: usize) -> Vec<f32> {
    assert!(w >= 1);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0f64;
    let mut q: std::collections::VecDeque<f64> = std::collections::VecDeque::new();
    for &x in xs {
        let e = f64::from(x) * f64::from(x);
        acc += e;
        q.push_back(e);
        if q.len() > w {
            acc -= q.pop_front().unwrap();
        }
        out.push((acc / q.len() as f64).sqrt() as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chirp_bounds_and_length() {
        let c = linear_chirp(0.0, 8000.0, 16000, 16000.0);
        assert_eq!(c.len(), 16000);
        assert!(c.iter().all(|&x| (-1.0..=1.0).contains(&x)));
    }

    #[test]
    fn chirp_instantaneous_frequency_endpoints() {
        assert!((chirp_freq_at(100.0, 900.0, 1000, 1000.0, 0) - 100.0).abs() < 1e-9);
        assert!((chirp_freq_at(100.0, 900.0, 1000, 1000.0, 1000) - 900.0).abs() < 1e-9);
    }

    #[test]
    fn tone_rms() {
        let t = tone(440.0, 16000, 16000.0, 1.0);
        let env = rms_envelope(&t, 512);
        // RMS of a unit sine is 1/sqrt(2)
        let tail = f64::from(env[8000]);
        assert!((tail - std::f64::consts::FRAC_1_SQRT_2).abs() < 0.01, "{tail}");
    }

    #[test]
    fn envelope_tracks_amplitude_steps() {
        let mut xs = tone(100.0, 2000, 8000.0, 0.1);
        xs.extend(tone(100.0, 2000, 8000.0, 1.0));
        let env = rms_envelope(&xs, 128);
        assert!(env[1500] < 0.2);
        assert!(env[3500] > 0.5);
    }
}
