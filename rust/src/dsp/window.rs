//! Window functions for windowed-sinc FIR design.

use std::f64::consts::PI;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    Rect,
    Hamming,
    Hann,
    Blackman,
}

impl Window {
    /// w[k] for k in 0..taps.
    pub fn coeffs(self, taps: usize) -> Vec<f64> {
        assert!(taps >= 1);
        let n = (taps - 1) as f64;
        (0..taps)
            .map(|k| {
                if taps == 1 {
                    return 1.0;
                }
                let x = k as f64 / n;
                match self {
                    Window::Rect => 1.0,
                    Window::Hamming => 0.54 - 0.46 * (2.0 * PI * x).cos(),
                    Window::Hann => 0.5 - 0.5 * (2.0 * PI * x).cos(),
                    Window::Blackman => {
                        0.42 - 0.5 * (2.0 * PI * x).cos() + 0.08 * (4.0 * PI * x).cos()
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric() {
        for w in [Window::Hamming, Window::Hann, Window::Blackman] {
            let c = w.coeffs(16);
            for k in 0..8 {
                assert!((c[k] - c[15 - k]).abs() < 1e-12, "{w:?} k={k}");
            }
        }
    }

    #[test]
    fn endpoints() {
        let hm = Window::Hamming.coeffs(11);
        assert!((hm[0] - 0.08).abs() < 1e-12);
        assert!((hm[5] - 1.0).abs() < 1e-12); // peak at centre
        let hn = Window::Hann.coeffs(11);
        assert!(hn[0].abs() < 1e-12);
        let bk = Window::Blackman.coeffs(11);
        assert!(bk[0].abs() < 1e-9);
    }

    #[test]
    fn rect_is_ones() {
        assert!(Window::Rect.coeffs(5).iter().all(|&x| x == 1.0));
    }

    #[test]
    fn single_tap() {
        assert_eq!(Window::Hamming.coeffs(1), vec![1.0]);
    }
}
