//! Multirate octave band plan (paper §III-C, Fig. 3, following the
//! CAR-lite multi-rate frequency model [28]).
//!
//! The spectrum is split into `n_octaves` octaves; octave `o` runs at the
//! decimated rate fs / 2^o and hosts `filters_per_octave` band-pass
//! filters covering the top octave [rate/4, rate/2] of its local rate.
//! Each octave transition applies an anti-aliasing low pass (cutoff 1/4)
//! followed by a factor-2 decimation. Because every octave sees the same
//! *normalised* band, a fixed low filter order (the paper's 15 /
//! 16 taps) suffices for every band — that is exactly the Fig. 4 story.

use super::fir::{self, FirFilter};
use super::greenwood;
use super::window::Window;

/// How centre frequencies are placed inside each octave band.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Spacing {
    /// Equally spaced edges inside the octave (paper: "cutoff frequencies
    /// is equally spaced within the octaves").
    Uniform,
    /// Uniform on the Greenwood cochlear place axis inside the octave.
    Greenwood,
}

/// One band of the plan.
#[derive(Clone, Debug)]
pub struct Band {
    /// Global index p (0-based; paper's Phi_{p+1}).
    pub p: usize,
    pub octave: usize,
    /// Local sampling rate of this band's octave (Hz).
    pub local_rate: f64,
    /// Band edges in Hz (global, physical).
    pub f1_hz: f64,
    pub f2_hz: f64,
    pub center_hz: f64,
}

#[derive(Clone, Debug)]
pub struct BandPlan {
    pub sample_rate: f64,
    pub n_octaves: usize,
    pub filters_per_octave: usize,
    pub bp_taps: usize,
    pub lp_taps: usize,
    pub spacing: Spacing,
    pub window: Window,
}

impl BandPlan {
    /// The paper's configuration: 16 kHz, 6 octaves x 5 filters,
    /// 16-tap band pass (order 15), 6-tap low pass.
    pub fn paper_default() -> BandPlan {
        BandPlan {
            sample_rate: 16_000.0,
            n_octaves: 6,
            filters_per_octave: 5,
            bp_taps: 16,
            lp_taps: 6,
            spacing: Spacing::Uniform,
            window: Window::Hamming,
        }
    }

    pub fn n_filters(&self) -> usize {
        self.n_octaves * self.filters_per_octave
    }

    pub fn octave_rate(&self, o: usize) -> f64 {
        self.sample_rate / f64::from(1u32 << o)
    }

    /// All bands, octave-major (octave 0 = highest frequencies first,
    /// matching the paper's descending cut-off arrangement).
    pub fn bands(&self) -> Vec<Band> {
        let mut out = Vec::with_capacity(self.n_filters());
        for o in 0..self.n_octaves {
            let rate = self.octave_rate(o);
            let (lo, hi) = (rate / 4.0, rate / 2.0);
            let edges = self.octave_edges(lo, hi);
            for i in 0..self.filters_per_octave {
                let (f1, f2) = (edges[i], edges[i + 1]);
                out.push(Band {
                    p: o * self.filters_per_octave + i,
                    octave: o,
                    local_rate: rate,
                    f1_hz: f1,
                    f2_hz: f2,
                    center_hz: 0.5 * (f1 + f2),
                });
            }
        }
        out
    }

    fn octave_edges(&self, lo: f64, hi: f64) -> Vec<f64> {
        let f = self.filters_per_octave;
        match self.spacing {
            Spacing::Uniform => (0..=f)
                .map(|i| lo + (hi - lo) * i as f64 / f as f64)
                .collect(),
            Spacing::Greenwood => {
                let xl = greenwood::freq_to_place(lo);
                let xh = greenwood::freq_to_place(hi);
                (0..=f)
                    .map(|i| greenwood::place_to_freq(xl + (xh - xl) * i as f64 / f as f64))
                    .collect()
            }
        }
    }

    /// Band-pass coefficients per band, designed at each band's *local*
    /// rate with the fixed low order (`bp_taps`). Layout: [octave][filter].
    pub fn bp_coeffs(&self) -> Vec<Vec<Vec<f64>>> {
        let bands = self.bands();
        (0..self.n_octaves)
            .map(|o| {
                bands
                    .iter()
                    .filter(|b| b.octave == o)
                    .map(|b| {
                        let rate = b.local_rate;
                        let f1 = (b.f1_hz / rate).max(0.01);
                        let f2 = (b.f2_hz / rate).min(0.497);
                        fir::bandpass(f1, f2, self.bp_taps, self.window)
                    })
                    .collect()
            })
            .collect()
    }

    /// Anti-aliasing low-pass per octave transition (n_octaves - 1 of
    /// them), cutoff 1/4 of the local rate (the next octave's Nyquist) —
    /// any lower and the top band of the next octave is attenuated.
    pub fn lp_coeffs(&self) -> Vec<Vec<f64>> {
        (0..self.n_octaves - 1)
            .map(|_| fir::lowpass(0.25, self.lp_taps, self.window))
            .collect()
    }

    /// Flattened f32 coefficient tensors in the HLO layout
    /// (bp: [O, F, bp_taps] row-major; lp: [O-1, lp_taps]).
    pub fn coeff_tensors(&self) -> (Vec<f32>, Vec<f32>) {
        let bp: Vec<f32> = self
            .bp_coeffs()
            .iter()
            .flatten()
            .flatten()
            .map(|&x| x as f32)
            .collect();
        let lp: Vec<f32> = self
            .lp_coeffs()
            .iter()
            .flatten()
            .map(|&x| x as f32)
            .collect();
        assert_eq!(bp.len(), self.n_octaves * self.filters_per_octave * self.bp_taps);
        assert_eq!(lp.len(), (self.n_octaves - 1) * self.lp_taps);
        (bp, lp)
    }

    /// FIR orders a *non-multirate* (direct, full-rate) design needs for
    /// the same bands — the paper's Fig. 4(a): order 15 at the top octave,
    /// doubling per octave, clamped at 200 ("filter order ranges from 15
    /// to 200").
    pub fn direct_orders(&self) -> Vec<usize> {
        (0..self.n_octaves)
            .map(|o| ((self.bp_taps - 1) << o).min(200))
            .collect()
    }

    /// Direct full-rate band-pass design per band (Fig. 4a comparator).
    pub fn direct_bp_coeffs(&self) -> Vec<Vec<f64>> {
        let orders = self.direct_orders();
        self.bands()
            .iter()
            .map(|b| {
                let f1 = (b.f1_hz / self.sample_rate).max(0.002);
                let f2 = (b.f2_hz / self.sample_rate).min(0.497);
                fir::bandpass(f1, f2, orders[b.octave] + 1, self.window)
            })
            .collect()
    }
}

/// Streaming float multirate filter bank (the conventional-MAC reference
/// path used by Fig. 4b and the float feature extractor).
pub struct MultirateFirBank {
    plan: BandPlan,
    bp: Vec<Vec<FirFilter>>, // [octave][filter]
    lp: Vec<FirFilter>,      // [octave transition]
    /// decimation phase per transition (keep every 2nd sample)
    phase: Vec<bool>,
}

impl MultirateFirBank {
    pub fn new(plan: &BandPlan) -> MultirateFirBank {
        let bp = plan
            .bp_coeffs()
            .into_iter()
            .map(|oct| oct.into_iter().map(FirFilter::new).collect())
            .collect();
        let lp = plan
            .lp_coeffs()
            .into_iter()
            .map(FirFilter::new)
            .collect();
        MultirateFirBank {
            plan: plan.clone(),
            bp,
            lp,
            phase: vec![false; plan.n_octaves - 1],
        }
    }

    pub fn plan(&self) -> &BandPlan {
        &self.plan
    }

    pub fn reset(&mut self) {
        self.bp.iter_mut().flatten().for_each(FirFilter::reset);
        self.lp.iter_mut().for_each(FirFilter::reset);
        self.phase.iter_mut().for_each(|p| *p = false);
    }

    /// Process a block; returns per-band output blocks (octave o's block
    /// is len/2^o samples long — its local rate).
    pub fn process(&mut self, xs: &[f32]) -> Vec<Vec<f32>> {
        let n_oct = self.plan.n_octaves;
        let f = self.plan.filters_per_octave;
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); n_oct * f];
        let mut sig = xs.to_vec();
        for o in 0..n_oct {
            for (i, filt) in self.bp[o].iter_mut().enumerate() {
                outs[o * f + i] = filt.process(&sig);
            }
            if o < n_oct - 1 {
                let low = self.lp[o].process(&sig);
                let mut dec = Vec::with_capacity(low.len() / 2 + 1);
                for &v in &low {
                    if !self.phase[o] {
                        dec.push(v);
                    }
                    self.phase[o] = !self.phase[o];
                }
                sig = dec;
            }
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::chirp;

    #[test]
    fn paper_plan_shape() {
        let plan = BandPlan::paper_default();
        let bands = plan.bands();
        assert_eq!(bands.len(), 30);
        // octave 0 covers [4k, 8k] at 16 kHz
        assert!((bands[0].f1_hz - 4000.0).abs() < 1e-9);
        assert!((bands[4].f2_hz - 8000.0).abs() < 1e-9);
        // last octave at 500 Hz covers [125, 250]
        let last = &bands[29];
        assert!((last.local_rate - 500.0).abs() < 1e-9);
        assert!((last.f2_hz - 250.0).abs() < 1e-9);
    }

    #[test]
    fn bands_cover_contiguously_within_octave() {
        let plan = BandPlan::paper_default();
        for w in plan.bands().chunks(5) {
            for pair in w.windows(2) {
                assert!((pair[0].f2_hz - pair[1].f1_hz).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn greenwood_spacing_monotone() {
        let mut plan = BandPlan::paper_default();
        plan.spacing = Spacing::Greenwood;
        let bands = plan.bands();
        for w in bands.chunks(5) {
            for pair in w.windows(2) {
                assert!(pair[1].center_hz > pair[0].center_hz);
            }
        }
    }

    #[test]
    fn coeff_tensor_shapes() {
        let plan = BandPlan::paper_default();
        let (bp, lp) = plan.coeff_tensors();
        assert_eq!(bp.len(), 6 * 5 * 16);
        assert_eq!(lp.len(), 5 * 6);
    }

    #[test]
    fn direct_orders_match_paper_range() {
        let plan = BandPlan::paper_default();
        let orders = plan.direct_orders();
        assert_eq!(orders[0], 15);
        assert_eq!(*orders.last().unwrap(), 200);
        assert!(orders.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn tone_lands_in_its_band() {
        // a tone at each band centre produces max energy in (or within
        // half an octave of) that band. The order-15 filters of the paper
        // are shallow, so the check is frequency-aware: index adjacency
        // is meaningless across octave boundaries (p=4's frequency
        // neighbour is p=0 of the previous octave block).
        let plan = BandPlan::paper_default();
        let mut bank = MultirateFirBank::new(&plan);
        let bands = plan.bands();
        for &p in &[0usize, 7, 14, 22, 29] {
            bank.reset();
            let f = bands[p].center_hz;
            let sig = chirp::tone(f, 16_384, plan.sample_rate, 1.0);
            let outs = bank.process(&sig);
            let energy: Vec<f64> = outs
                .iter()
                .map(|ys| {
                    let skip = ys.len() / 4;
                    ys[skip..].iter().map(|&y| f64::from(y).powi(2)).sum::<f64>()
                        / (ys.len() - skip).max(1) as f64
                })
                .collect();
            let best = crate::util::stats::argmax(&energy);
            let ratio = (bands[best].center_hz / f).log2().abs();
            assert!(
                ratio <= 0.55,
                "tone {f:.0} Hz p={p} best={best} ({:.0} Hz) energies={energy:?}",
                bands[best].center_hz
            );
        }
    }

    #[test]
    fn streaming_chunks_equal_whole() {
        let plan = BandPlan::paper_default();
        let sig = chirp::linear_chirp(50.0, 7900.0, 4096, plan.sample_rate);
        let mut whole = MultirateFirBank::new(&plan);
        let yw = whole.process(&sig);
        let mut chunked = MultirateFirBank::new(&plan);
        let mut yc: Vec<Vec<f32>> = vec![Vec::new(); 30];
        for chunk in sig.chunks(512) {
            for (acc, part) in yc.iter_mut().zip(chunked.process(chunk)) {
                acc.extend(part);
            }
        }
        for (a, b) in yw.iter().zip(&yc) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn decimated_lengths() {
        let plan = BandPlan::paper_default();
        let mut bank = MultirateFirBank::new(&plan);
        let outs = bank.process(&vec![0.0f32; 2048]);
        for o in 0..6 {
            assert_eq!(outs[o * 5].len(), 2048 >> o);
        }
    }
}
