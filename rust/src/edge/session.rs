//! Per-sensor session lifecycle: turns a never-ending gated audio stream
//! into the clip-aligned [`FrameTask`]s the coordinator consumes.
//!
//! State machine:
//!
//! ```text
//!           gate onset                    clip_frames emitted
//!   Idle ----------------> Triggered -------------------------+
//!    ^   (emit pre-trigger               |                    |
//!    |    lookback + live frames)        | gate already shut  v
//!    +-----------------------------------+              Draining
//!    ^                                                        |
//!    +------------------- gate shut --------------------------+
//! ```
//!
//! * **Idle** — ambient audio flows into the lookback ring only; nothing
//!   reaches the coordinator (this is the compute + bandwidth saving).
//! * **Triggered** — a clip is being assembled: the pre-trigger frames
//!   from the ring, then live frames, exactly `clip_frames` in total so
//!   the coordinator's accumulator semantics are untouched.
//! * **Draining** — the clip is full but the gate is still open; frames
//!   are counted and discarded so one long event yields one clip instead
//!   of retriggering on its own tail. A watchdog resets a gate that is
//!   stuck open (e.g. a floor poisoned by a cold-start transient).
//!
//! Duty cycling is owned here too: an asleep sensor produces nothing,
//! and the session accounts awake/asleep frames for the duty report.

use super::ring::FrameRing;
use super::vad::{EnergyGate, GateConfig};
use crate::coordinator::FrameTask;
use std::time::Instant;

/// Label carried by frames of clips that do not overlap any ground-truth
/// event (fleet bookkeeping; never a valid class index).
pub const AMBIENT_LABEL: usize = usize::MAX;

/// Periodic sleep schedule in frame ticks.
#[derive(Clone, Copy, Debug)]
pub struct DutyCycle {
    pub awake_frames: u32,
    pub sleep_frames: u32,
    /// schedule offset, so a fleet's sensors stagger their wakeups
    pub phase: u32,
}

impl DutyCycle {
    pub fn always_on() -> DutyCycle {
        DutyCycle {
            awake_frames: 1,
            sleep_frames: 0,
            phase: 0,
        }
    }

    pub fn period(&self) -> u32 {
        (self.awake_frames + self.sleep_frames).max(1)
    }

    pub fn awake_at(&self, tick: u64) -> bool {
        if self.sleep_frames == 0 {
            return true;
        }
        ((tick + u64::from(self.phase)) % u64::from(self.period()))
            < u64::from(self.awake_frames)
    }

    /// Fraction of ticks the sensor is awake.
    pub fn factor(&self) -> f64 {
        f64::from(self.awake_frames.max(1)) / f64::from(self.period())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    Idle,
    Triggered,
    Draining,
}

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub stream: u64,
    pub frame_len: usize,
    pub clip_frames: usize,
    /// lookback frames emitted before the onset frame (< clip_frames)
    pub pre_trigger_frames: usize,
    pub gate: GateConfig,
    pub duty: DutyCycle,
    /// frames the gate may stay open post-clip before it is reset
    pub max_drain_frames: u32,
}

impl SessionConfig {
    pub fn new(stream: u64, frame_len: usize, clip_frames: usize) -> SessionConfig {
        SessionConfig {
            stream,
            frame_len,
            clip_frames,
            pre_trigger_frames: 2,
            gate: GateConfig::default(),
            duty: DutyCycle::always_on(),
            max_drain_frames: 32,
        }
    }
}

/// Counters the fleet report aggregates.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    pub frames_seen: u64,
    pub frames_asleep: u64,
    /// awake frames the gate kept away from the coordinator
    pub frames_gated_off: u64,
    pub frames_drained: u64,
    pub trigger_onsets: u64,
    pub clips_emitted: u64,
    pub gate_resets: u64,
    /// onsets whose pre-trigger lookback was shorter than configured
    /// (not enough history in the ring yet)
    pub lookback_truncated: u64,
}

/// One sensor stream's ingest front end.
pub struct EdgeSession {
    cfg: SessionConfig,
    gate: EnergyGate,
    ring: FrameRing,
    state: SessionState,
    clip_seq: u64,
    frames_into_clip: usize,
    /// sticky ground-truth label for the clip being assembled: once any
    /// emitted frame overlaps an event, the whole clip reports that
    /// class (the dispatcher keeps the last frame's label, so trailing
    /// post-event frames must not relabel the clip ambient)
    clip_label: usize,
    drained_this_event: u32,
    pub stats: SessionStats,
}

impl EdgeSession {
    pub fn new(cfg: SessionConfig) -> EdgeSession {
        assert!(
            cfg.pre_trigger_frames < cfg.clip_frames,
            "pre-trigger lookback must leave room for live frames"
        );
        let gate = EnergyGate::new(cfg.gate);
        let ring = FrameRing::new(cfg.pre_trigger_frames.max(1), cfg.frame_len);
        EdgeSession {
            cfg,
            gate,
            ring,
            state: SessionState::Idle,
            clip_seq: 0,
            frames_into_clip: 0,
            clip_label: AMBIENT_LABEL,
            drained_this_event: 0,
            stats: SessionStats::default(),
        }
    }

    pub fn state(&self) -> SessionState {
        self.state
    }

    pub fn stream(&self) -> u64 {
        self.cfg.stream
    }

    pub fn clip_seq(&self) -> u64 {
        self.clip_seq
    }

    /// Lookback frames displaced unread (ring overruns).
    pub fn ring_overruns(&self) -> u64 {
        self.ring.overwritten()
    }

    pub fn awake(&self, tick: u64) -> bool {
        self.cfg.duty.awake_at(tick)
    }

    /// Account one asleep tick (the caller skips synthesis entirely).
    pub fn note_asleep(&mut self) {
        self.stats.frames_asleep += 1;
    }

    /// Feed one awake frame; any clip frames it releases are appended to
    /// `out` (pre-trigger lookback first, in order). `label` tags the
    /// emitted frames for evaluation ([`AMBIENT_LABEL`] when no event is
    /// known to be present).
    pub fn push_frame(&mut self, frame: &[f32], label: usize, out: &mut Vec<FrameTask>) {
        assert_eq!(frame.len(), self.cfg.frame_len, "frame length mismatch");
        self.stats.frames_seen += 1;
        let q = self.gate.quantize(frame);
        let g = self.gate.push_frame(&q);
        match self.state {
            SessionState::Idle => {
                if g.open {
                    self.state = SessionState::Triggered;
                    self.stats.trigger_onsets += 1;
                    crate::metric_counter!("edge_gate_triggers_total").inc();
                    self.frames_into_clip = 0;
                    let lookback: Vec<Vec<f32>> = self
                        .ring
                        .last_n(self.cfg.pre_trigger_frames)
                        .into_iter()
                        .map(<[f32]>::to_vec)
                        .collect();
                    if lookback.len() < self.cfg.pre_trigger_frames {
                        self.stats.lookback_truncated += 1;
                    }
                    for lb in &lookback {
                        self.emit(lb, label, out);
                    }
                    self.ring.clear();
                    self.emit(frame, label, out);
                    self.after_emit();
                } else {
                    self.ring.push(frame);
                    self.stats.frames_gated_off += 1;
                }
            }
            SessionState::Triggered => {
                self.emit(frame, label, out);
                self.after_emit();
            }
            SessionState::Draining => {
                self.stats.frames_drained += 1;
                self.drained_this_event += 1;
                self.ring.push(frame);
                if !g.open {
                    self.state = SessionState::Idle;
                } else if self.drained_this_event >= self.cfg.max_drain_frames {
                    // watchdog: a gate latched open starves the stream
                    self.gate.reset();
                    self.stats.gate_resets += 1;
                    crate::metric_counter!("edge_gate_resets_total").inc();
                    self.state = SessionState::Idle;
                }
            }
        }
    }

    fn emit(&mut self, frame: &[f32], label: usize, out: &mut Vec<FrameTask>) {
        if label != AMBIENT_LABEL {
            self.clip_label = label;
        }
        out.push(FrameTask {
            stream: self.cfg.stream,
            clip_seq: self.clip_seq,
            frame_idx: self.frames_into_clip,
            data: frame.to_vec(),
            label: self.clip_label,
            t_gen: Instant::now(),
        });
        self.frames_into_clip += 1;
    }

    /// Close the clip when it is full; decide where the event goes next.
    fn after_emit(&mut self) {
        if self.frames_into_clip >= self.cfg.clip_frames {
            self.clip_seq += 1;
            self.frames_into_clip = 0;
            self.clip_label = AMBIENT_LABEL;
            self.stats.clips_emitted += 1;
            self.drained_this_event = 0;
            self.ring.clear();
            self.state = if self.gate.is_open() {
                SessionState::Draining
            } else {
                SessionState::Idle
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FRAME: usize = 256;

    fn config(stream: u64) -> SessionConfig {
        let mut cfg = SessionConfig::new(stream, FRAME, 4);
        cfg.pre_trigger_frames = 2;
        cfg
    }

    fn ambient(i: u64) -> Vec<f32> {
        let mut rng = crate::util::prng::Pcg32::new(0xa3b1 ^ i);
        (0..FRAME).map(|_| (rng.normal() as f32) * 0.02).collect()
    }

    fn burst() -> Vec<f32> {
        (0..FRAME)
            .map(|i| if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect()
    }

    fn settle(s: &mut EdgeSession, out: &mut Vec<FrameTask>, n: u64) {
        for i in 0..n {
            s.push_frame(&ambient(i), AMBIENT_LABEL, out);
        }
        assert!(out.is_empty(), "ambient audio must stay on the edge");
        assert_eq!(s.state(), SessionState::Idle);
    }

    #[test]
    fn event_emits_one_full_clip_with_lookback() {
        let mut s = EdgeSession::new(config(3));
        let mut out = Vec::new();
        settle(&mut s, &mut out, 30);
        // 6 loud frames: onset + clip assembly + drain
        for _ in 0..6 {
            s.push_frame(&burst(), 2, &mut out);
        }
        assert_eq!(out.len(), 4, "exactly one clip of clip_frames tasks");
        for (i, t) in out.iter().enumerate() {
            assert_eq!(t.stream, 3);
            assert_eq!(t.clip_seq, 0);
            assert_eq!(t.frame_idx, i);
            assert_eq!(t.label, 2);
            assert_eq!(t.data.len(), FRAME);
        }
        // first two tasks are the pre-trigger ambient lookback (quiet),
        // the rest are the loud live frames
        let rms = |d: &[f32]| d.iter().map(|&x| x * x).sum::<f32>() / d.len() as f32;
        assert!(rms(&out[0].data) < 0.01);
        assert!(rms(&out[2].data) > 0.1);
        assert_eq!(s.stats.clips_emitted, 1);
        assert_eq!(s.stats.trigger_onsets, 1);
        // long event: the tail drains instead of retriggering
        assert_eq!(s.state(), SessionState::Draining);
        assert!(s.stats.frames_drained > 0);
    }

    #[test]
    fn gate_closure_returns_to_idle_and_next_event_gets_next_clip_seq() {
        let mut s = EdgeSession::new(config(0));
        let mut out = Vec::new();
        settle(&mut s, &mut out, 30);
        for _ in 0..5 {
            s.push_frame(&burst(), 1, &mut out);
        }
        out.clear();
        // quiet again: drain ends within a few frames (hangover + release)
        for i in 0..6 {
            s.push_frame(&ambient(100 + i), AMBIENT_LABEL, &mut out);
        }
        assert_eq!(s.state(), SessionState::Idle);
        assert!(out.is_empty());
        // second event
        for _ in 0..5 {
            s.push_frame(&burst(), 7, &mut out);
        }
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|t| t.clip_seq == 1));
        assert_eq!(s.stats.clips_emitted, 2);
    }

    #[test]
    fn short_history_yields_shorter_lookback_not_a_stall() {
        // onset right after warmup: only one frame in the ring — the clip
        // starts with a 1-frame lookback instead of two and still fills
        let mut cfg = config(9);
        cfg.gate.warmup_frames = 1;
        let mut s = EdgeSession::new(cfg);
        let mut out = Vec::new();
        s.push_frame(&ambient(0), AMBIENT_LABEL, &mut out); // warmup + 1 ring frame
        assert!(out.is_empty());
        for _ in 0..8 {
            s.push_frame(&burst(), 5, &mut out);
        }
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].frame_idx, 0);
        let rms = |d: &[f32]| d.iter().map(|&x| x * x).sum::<f32>() / d.len() as f32;
        assert!(rms(&out[0].data) < 0.01, "first task is the ambient lookback");
        assert!(rms(&out[1].data) > 0.1, "second task is already the event");
        assert_eq!(s.stats.clips_emitted, 1);
        assert_eq!(s.stats.lookback_truncated, 1);
    }

    #[test]
    fn duty_cycle_schedule_and_factor() {
        let d = DutyCycle {
            awake_frames: 3,
            sleep_frames: 1,
            phase: 0,
        };
        let pattern: Vec<bool> = (0..8).map(|t| d.awake_at(t)).collect();
        assert_eq!(
            pattern,
            vec![true, true, true, false, true, true, true, false]
        );
        assert!((d.factor() - 0.75).abs() < 1e-12);
        assert!(DutyCycle::always_on().awake_at(12345));
        let shifted = DutyCycle {
            awake_frames: 3,
            sleep_frames: 1,
            phase: 1,
        };
        assert!(!shifted.awake_at(2));
    }

    #[test]
    fn watchdog_resets_a_latched_gate() {
        let mut cfg = config(1);
        cfg.max_drain_frames = 3;
        let mut s = EdgeSession::new(cfg);
        let mut out = Vec::new();
        settle(&mut s, &mut out, 30);
        // a very long event: clip, then the drain watchdog fires
        for _ in 0..12 {
            s.push_frame(&burst(), 0, &mut out);
        }
        assert!(s.stats.gate_resets >= 1);
        assert_eq!(out.len(), 4, "still exactly one clip");
    }
}
