//! Fixed-capacity frame ring with pre-trigger lookback.
//!
//! The gate decides an event started only *after* hearing it, so the
//! session must be able to emit the frames from just before the onset.
//! This ring keeps the last `capacity` gated-off frames in pre-allocated
//! slots (single-producer single-consumer friendly: plain index
//! arithmetic, no allocation after construction) and counts every
//! overwrite, which is the session's lookback-overrun metric.

/// Ring of equally sized audio frames, newest overwrites oldest.
#[derive(Clone, Debug)]
pub struct FrameRing {
    slots: Vec<Vec<f32>>,
    frame_len: usize,
    /// next slot to write
    head: usize,
    /// number of valid slots (saturates at capacity)
    len: usize,
    /// frames displaced before ever being read out
    overwritten: u64,
}

impl FrameRing {
    pub fn new(capacity: usize, frame_len: usize) -> FrameRing {
        assert!(capacity >= 1, "ring needs at least one slot");
        FrameRing {
            slots: vec![vec![0.0; frame_len]; capacity],
            frame_len,
            head: 0,
            len: 0,
            overwritten: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Frames displaced by later pushes without being read.
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Copy a frame into the ring, displacing the oldest when full.
    pub fn push(&mut self, frame: &[f32]) {
        assert_eq!(frame.len(), self.frame_len, "frame length mismatch");
        let cap = self.slots.len();
        if self.len == cap {
            self.overwritten += 1;
        } else {
            self.len += 1;
        }
        self.slots[self.head].copy_from_slice(frame);
        self.head = (self.head + 1) % cap;
    }

    /// The newest `n` frames in chronological order (fewer if the ring
    /// holds fewer).
    pub fn last_n(&self, n: usize) -> Vec<&[f32]> {
        let take = n.min(self.len);
        let cap = self.slots.len();
        (0..take)
            .map(|i| {
                // i = 0 is the oldest of the `take` newest
                let idx = (self.head + cap - take + i) % cap;
                self.slots[idx].as_slice()
            })
            .collect()
    }

    /// Forget everything (keeps the overwrite counter).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(v: f32) -> Vec<f32> {
        vec![v; 4]
    }

    #[test]
    fn fills_then_wraps_in_order() {
        let mut r = FrameRing::new(3, 4);
        assert!(r.is_empty());
        for v in 0..5 {
            r.push(&frame(v as f32));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.overwritten(), 2);
        let last = r.last_n(3);
        assert_eq!(last[0][0], 2.0);
        assert_eq!(last[1][0], 3.0);
        assert_eq!(last[2][0], 4.0);
    }

    #[test]
    fn last_n_partial_and_oversized() {
        let mut r = FrameRing::new(4, 4);
        r.push(&frame(7.0));
        r.push(&frame(8.0));
        let two = r.last_n(8);
        assert_eq!(two.len(), 2);
        assert_eq!(two[0][0], 7.0);
        assert_eq!(two[1][0], 8.0);
        let one = r.last_n(1);
        assert_eq!(one[0][0], 8.0);
        assert!(r.last_n(0).is_empty());
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let mut r = FrameRing::new(2, 4);
        r.push(&frame(1.0));
        r.push(&frame(2.0));
        r.push(&frame(3.0));
        assert_eq!(r.overwritten(), 1);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.overwritten(), 1);
        r.push(&frame(9.0));
        assert_eq!(r.last_n(2).len(), 1);
        assert_eq!(r.last_n(1)[0][0], 9.0);
    }

    #[test]
    fn single_slot_ring() {
        let mut r = FrameRing::new(1, 4);
        r.push(&frame(1.0));
        r.push(&frame(2.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r.last_n(1)[0][0], 2.0);
        assert_eq!(r.overwritten(), 1);
    }

    #[test]
    #[should_panic(expected = "frame length mismatch")]
    fn wrong_frame_length_panics() {
        let mut r = FrameRing::new(2, 4);
        r.push(&[0.0; 3]);
    }
}
