//! Edge ingest subsystem: the continuous-audio front end that stands
//! between raw sensors and the serving coordinator (paper Fig. 1's
//! remote wildlife monitor, made concrete).
//!
//! The coordinator consumes clip-aligned [`FrameTask`]s; real sensors
//! produce never-ending audio on a bandwidth-starved uplink. This module
//! closes that gap with the same design discipline as the paper's
//! datapath — the detection gate is built purely from add/subtract/
//! shift/compare over [`crate::fixed::q`] types, so it is as
//! FPGA-honest as the MP kernel it guards:
//!
//! * [`vad`] — multiplierless event gate (shift-EMA envelopes, hysteresis
//!   comparator, hangover counter),
//! * [`ring`] — fixed-capacity frame ring giving the gate pre-trigger
//!   lookback,
//! * [`session`] — per-sensor lifecycle (Idle → Triggered → Draining),
//!   duty-cycle accounting and clip assembly,
//! * [`uplink`] — token-bucket bandwidth budget modelling the remote
//!   link, with the bytes-saved-vs-raw-streaming accounting,
//! * [`fleet`] — the fleet simulator: hundreds of duty-cycled streams
//!   with ground-truth embedded events, driven through an owned
//!   coordinator [`Pipeline`] (or a multi-lane [`ShardedPipeline`]) and
//!   scored for recall / false triggers / bandwidth.
//!
//! [`FrameTask`]: crate::coordinator::FrameTask
//! [`Pipeline`]: crate::coordinator::Pipeline
//! [`ShardedPipeline`]: crate::coordinator::ShardedPipeline

pub mod fleet;
pub mod ring;
pub mod session;
pub mod uplink;
pub mod vad;

pub use session::AMBIENT_LABEL;
