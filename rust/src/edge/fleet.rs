//! Fleet-scale ingest simulation: hundreds of duty-cycled sensor streams
//! of continuous synthetic ambient audio with sparse embedded ESC-10
//! events, pushed through gate → session → coordinator → uplink in
//! virtual time, with ground truth retained so the report can score
//! event recall, false-trigger rate and the uplink bytes-saved ratio.

use super::session::{DutyCycle, EdgeSession, SessionConfig, SessionState, AMBIENT_LABEL};
use super::uplink::{Uplink, UplinkConfig, UplinkStats};
use super::vad::GateConfig;
use crate::config::EdgeConfig;
use crate::coordinator::batcher::BatcherPolicy;
use crate::coordinator::dispatch::{Lane, PipelineBuilder};
use crate::coordinator::metrics::{render_lanes, LaneStats};
use crate::coordinator::shard::{AnyLane, ShardedPipeline};
use crate::coordinator::{ClassifyResult, FrameTask};
use crate::datasets::esc10;
use crate::runtime::backend::InferenceBackend;
use crate::train::TrainedModel;
use crate::util::prng::Pcg32;
use crate::util::table::Table;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Full fleet shape. Use [`FleetConfig::from_edge`] for the CLI path.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub n_streams: usize,
    /// frames of virtual time per stream
    pub ticks: u64,
    pub events_per_stream: usize,
    /// force every embedded event to one ESC-10 class (None = random)
    pub event_class: Option<usize>,
    pub seed: u64,
    pub ambient_rms: f64,
    pub event_gain: f64,
    pub frame_len: usize,
    pub clip_frames: usize,
    pub pre_trigger_frames: usize,
    pub duty_awake: u32,
    pub duty_sleep: u32,
    pub gate: GateConfig,
    pub uplink: UplinkConfig,
    pub policy: BatcherPolicy,
    pub queue_capacity: usize,
    pub sample_rate: f64,
    /// compute lanes; 1 = single synchronous pipeline, >1 = sharded
    pub shards: usize,
}

impl FleetConfig {
    /// Instantiate for a backend's clip geometry from the CLI-level
    /// [`EdgeConfig`]. The gate's floor time constant is derived from
    /// `frame_len` so it always spans ~8 frames — it must cover several
    /// frames or the within-frame floor adaptation chases an event
    /// before the frame-boundary decision sees it. CLI-reachable values
    /// are clamped into their valid ranges rather than asserted on.
    pub fn from_edge(
        e: &EdgeConfig,
        seed: u64,
        frame_len: usize,
        clip_frames: usize,
        sample_rate: f64,
    ) -> FleetConfig {
        // 2048-sample frames -> shift 14 (~16k samples); 256 -> shift 11
        let slow_shift = (frame_len * 8).next_power_of_two().trailing_zeros().min(20);
        let margin_shift = e.gate_margin_shift.min(6);
        let gate = GateConfig {
            slow_shift,
            warmup_frames: 12, // ~1.5 floor time constants, any frame_len
            margin_shift,
            hangover_frames: e.gate_hangover,
            release_shift: margin_shift + 1,
            ..GateConfig::default()
        };
        let ticks = ((e.seconds_per_stream * sample_rate / frame_len as f64).ceil() as u64).max(1);
        // a clip-upload message must fit the bucket or it is permanently
        // unsendable; grow the burst to hold at least one
        let clip_msg = (frame_len * clip_frames * 2 + 64) as f64;
        let burst = if e.upload_clips {
            e.uplink_burst_bytes.max(clip_msg)
        } else {
            e.uplink_burst_bytes
        };
        FleetConfig {
            n_streams: e.n_streams,
            ticks,
            events_per_stream: e.events_per_stream,
            event_class: None,
            seed,
            ambient_rms: e.ambient_rms,
            event_gain: e.event_gain,
            frame_len,
            clip_frames,
            pre_trigger_frames: e.pre_trigger_frames.min(clip_frames.saturating_sub(1)),
            duty_awake: e.duty_awake,
            duty_sleep: e.duty_sleep,
            gate,
            uplink: UplinkConfig {
                bytes_per_sec: e.uplink_bytes_per_sec,
                burst_bytes: burst,
                upload_clips: e.upload_clips,
                ..UplinkConfig::default()
            },
            policy: BatcherPolicy::default(),
            queue_capacity: 32,
            sample_rate,
            shards: e.shards,
        }
    }
}

/// One embedded event the simulator knows the truth about.
#[derive(Clone, Copy, Debug)]
pub struct GroundTruthEvent {
    pub stream: u64,
    pub class: usize,
    /// frame window [start, end)
    pub start: u64,
    pub end: u64,
}

#[derive(Clone, Copy, Debug)]
struct PlannedEvent {
    class: usize,
    start: u64,
    clip_index: u64,
}

/// A sensor stream: ambient noise generator + planned events + session.
struct SensorStream {
    session: EdgeSession,
    ambient_rng: Pcg32,
    events: Vec<PlannedEvent>,
    next_event: usize,
    /// synthesised samples of the currently overlapping event
    active: Option<Vec<f32>>,
}

impl SensorStream {
    /// Synthesise this stream's frame at `tick`; returns the audio and
    /// the ground-truth label of any overlapping event.
    fn next_frame(&mut self, tick: u64, cfg: &FleetConfig) -> (Vec<f32>, usize) {
        // retire events whose window has passed (possibly while asleep)
        while self.next_event < self.events.len()
            && tick >= self.events[self.next_event].start + cfg.clip_frames as u64
        {
            self.next_event += 1;
            self.active = None;
        }
        let mut frame: Vec<f32> = (0..cfg.frame_len)
            .map(|_| (self.ambient_rng.normal() * cfg.ambient_rms) as f32)
            .collect();
        let mut label = AMBIENT_LABEL;
        if let Some(ev) = self.events.get(self.next_event).copied() {
            if tick >= ev.start {
                let samples = self.active.get_or_insert_with(|| {
                    esc10::synth_clip(cfg.seed, ev.class, ev.clip_index).samples
                });
                let off = (tick - ev.start) as usize * cfg.frame_len;
                let end = (off + cfg.frame_len).min(samples.len());
                if off < end {
                    let gain = cfg.event_gain as f32;
                    for (f, &s) in frame.iter_mut().zip(&samples[off..end]) {
                        *f += gain * s;
                    }
                    label = ev.class;
                }
            }
        }
        (frame, label)
    }
}

/// Aggregate fleet report.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub streams: usize,
    pub ticks: u64,
    /// captured (awake) audio seconds across the fleet
    pub audio_seconds: f64,
    /// awake fraction actually realised by the duty schedule
    pub duty_factor: f64,
    /// fraction of awake frames the gate kept on the edge
    pub gated_off_fraction: f64,
    pub trigger_onsets: u64,
    pub clips_classified: u64,
    pub clips_aborted: u64,
    pub frames_dropped: u64,
    /// onsets that got a shorter pre-trigger lookback than configured
    pub lookback_truncated: u64,
    pub gate_resets: u64,
    pub events_total: usize,
    pub events_recalled: usize,
    pub false_triggers: u64,
    /// classification accuracy over clips matched to a ground-truth event
    pub matched_total: u64,
    pub matched_correct: u64,
    pub uplink: UplinkStats,
    pub bytes_saved_ratio: f64,
    pub wall: Duration,
    /// per-lane breakdown when the fleet classified through a
    /// [`ShardedPipeline`](crate::coordinator::ShardedPipeline); empty
    /// for a single-lane run
    pub per_lane: Vec<LaneStats>,
}

impl FleetReport {
    pub fn recall(&self) -> f64 {
        if self.events_total == 0 {
            0.0
        } else {
            self.events_recalled as f64 / self.events_total as f64
        }
    }

    /// False triggers per captured stream-hour.
    pub fn false_trigger_rate(&self) -> f64 {
        let hours = self.audio_seconds / 3600.0;
        if hours <= 0.0 {
            0.0
        } else {
            self.false_triggers as f64 / hours
        }
    }

    pub fn matched_accuracy(&self) -> f64 {
        if self.matched_total == 0 {
            0.0
        } else {
            self.matched_correct as f64 / self.matched_total as f64
        }
    }

    pub fn realtime_factor(&self) -> f64 {
        let w = self.wall.as_secs_f64();
        if w <= 0.0 {
            0.0
        } else {
            self.audio_seconds / w
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "fleet: {} streams x {} frames | captured audio {:.1}s \
             (duty {:.0}%) | wall {:.2}s ({:.1}x realtime)\n\
             gate: {:.1}% of awake frames held on the edge | onsets={} \
             lookback_truncated={} gate_resets={}\n\
             events: {}/{} recalled ({:.1}%) | false triggers={} \
             ({:.2}/stream-hour)\n\
             classify: clips={} aborted={} dropped_frames={} | matched \
             accuracy {:.1}% ({}/{})\n\
             uplink: sent {} msgs / {} B (dropped {} oversized {}) vs \
             raw {} B | bytes-saved {:.0}x",
            self.streams,
            self.ticks,
            self.audio_seconds,
            100.0 * self.duty_factor,
            self.wall.as_secs_f64(),
            self.realtime_factor(),
            100.0 * self.gated_off_fraction,
            self.trigger_onsets,
            self.lookback_truncated,
            self.gate_resets,
            self.events_recalled,
            self.events_total,
            100.0 * self.recall(),
            self.false_triggers,
            self.false_trigger_rate(),
            self.clips_classified,
            self.clips_aborted,
            self.frames_dropped,
            100.0 * self.matched_accuracy(),
            self.matched_correct,
            self.matched_total,
            self.uplink.msgs_sent,
            self.uplink.bytes_sent,
            self.uplink.msgs_dropped,
            self.uplink.msgs_oversized,
            self.uplink.raw_bytes_captured,
            self.bytes_saved_ratio,
        );
        s.push_str(&render_lanes(&self.per_lane));
        s
    }

    /// Key/value table for the CSV dump.
    pub fn table(&self) -> Table {
        let mut t = Table::new("edge fleet report", &["metric", "value"]);
        let mut kv = |k: &str, v: String| {
            t.row(vec![k.to_string(), v]);
        };
        kv("streams", self.streams.to_string());
        kv("ticks", self.ticks.to_string());
        kv("audio_seconds", format!("{:.2}", self.audio_seconds));
        kv("duty_factor", format!("{:.4}", self.duty_factor));
        kv("gated_off_fraction", format!("{:.4}", self.gated_off_fraction));
        kv("trigger_onsets", self.trigger_onsets.to_string());
        kv("events_total", self.events_total.to_string());
        kv("events_recalled", self.events_recalled.to_string());
        kv("recall", format!("{:.4}", self.recall()));
        kv("false_triggers", self.false_triggers.to_string());
        kv("false_triggers_per_hour", format!("{:.3}", self.false_trigger_rate()));
        kv("clips_classified", self.clips_classified.to_string());
        kv("clips_aborted", self.clips_aborted.to_string());
        kv("frames_dropped", self.frames_dropped.to_string());
        kv("matched_accuracy", format!("{:.4}", self.matched_accuracy()));
        kv("uplink_msgs_sent", self.uplink.msgs_sent.to_string());
        kv("uplink_bytes_sent", self.uplink.bytes_sent.to_string());
        kv("uplink_msgs_dropped", self.uplink.msgs_dropped.to_string());
        kv("uplink_msgs_oversized", self.uplink.msgs_oversized.to_string());
        kv("raw_bytes_captured", self.uplink.raw_bytes_captured.to_string());
        kv("bytes_saved_ratio", format!("{:.1}", self.bytes_saved_ratio));
        kv("wall_seconds", format!("{:.3}", self.wall.as_secs_f64()));
        t
    }
}

/// Plan this stream's events inside the usable window, one per chunk so
/// events never merge. Returns fewer events when the window is too small.
fn plan_events(cfg: &FleetConfig, rng: &mut Pcg32, stream: u64) -> Vec<PlannedEvent> {
    // gate warmup elapses on *awake* frames only, so the exclusion
    // window at the start must be scaled from awake frames to wall ticks
    let period = u64::from((cfg.duty_awake + cfg.duty_sleep).max(1));
    let awake = u64::from(cfg.duty_awake.max(1));
    let warmup_wall = (u64::from(cfg.gate.warmup_frames) * period).div_ceil(awake);
    let min_start = warmup_wall + cfg.pre_trigger_frames as u64 + 2;
    let guard = cfg.clip_frames as u64 + 4; // event + drain/settle gap
    let Some(span) = (cfg.ticks.saturating_sub(min_start)).checked_sub(guard) else {
        return Vec::new();
    };
    if cfg.events_per_stream == 0 {
        return Vec::new();
    }
    let chunk = span / cfg.events_per_stream as u64;
    let mut out = Vec::new();
    for e in 0..cfg.events_per_stream as u64 {
        if chunk < guard {
            break; // window too small for more events
        }
        let lo = min_start + e * chunk;
        let hi = lo + chunk - guard;
        let start = lo + u64::from(rng.below((hi - lo + 1) as u32));
        let class = match cfg.event_class {
            Some(c) => c,
            None => rng.below(10) as usize,
        };
        out.push(PlannedEvent {
            class,
            start,
            // clip indices disjoint from train (0..) and test (10_000..)
            clip_index: 20_000 + stream * 64 + e,
        });
    }
    out
}

/// Build the compute lane a [`FleetConfig`] asks for: a single
/// synchronous [`Pipeline`](crate::coordinator::Pipeline) when
/// `cfg.shards == 1` (the factory runs once on the caller's thread), a
/// [`ShardedPipeline`] otherwise (the factory runs once per worker
/// thread). Shared by the CLI and the wildlife_monitor example.
pub fn fleet_lane<B, F>(
    cfg: &FleetConfig,
    model: TrainedModel,
    factory: F,
) -> Result<AnyLane<B>>
where
    B: InferenceBackend + 'static,
    F: Fn(usize) -> Result<B> + Send + Sync + 'static,
{
    if cfg.shards > 1 {
        Ok(AnyLane::Sharded(
            ShardedPipeline::builder(cfg.shards, factory, model)
                .policy(cfg.policy)
                .queue_capacity(cfg.queue_capacity)
                .build()?,
        ))
    } else {
        Ok(AnyLane::Single(
            PipelineBuilder::new(factory(0)?, model)
                .policy(cfg.policy)
                .queue_capacity(cfg.queue_capacity)
                .build(),
        ))
    }
}

/// Drive the whole fleet through an owned compute lane in virtual time.
/// `lane` is any [`Lane`] — typically [`fleet_lane`]'s result, or a
/// hand-built [`Pipeline`](crate::coordinator::Pipeline) /
/// [`ShardedPipeline`] with the fleet's `policy` / `queue_capacity`.
pub fn run_fleet<L: Lane>(
    mut lane: L,
    cfg: &FleetConfig,
) -> Result<(FleetReport, Vec<ClassifyResult>)> {
    ensure!(
        lane.frame_len() == cfg.frame_len && lane.clip_frames() == cfg.clip_frames,
        "lane clip geometry ({}/{}) does not match the fleet config ({}/{})",
        lane.frame_len(),
        lane.clip_frames(),
        cfg.frame_len,
        cfg.clip_frames
    );
    ensure!(
        (lane.sample_rate() - cfg.sample_rate).abs() < 1e-6,
        "lane sample rate ({} Hz) does not match the fleet config ({} Hz)",
        lane.sample_rate(),
        cfg.sample_rate
    );
    // fail at config time rather than silently black-holing every clip
    // report against a burst that can never hold one
    cfg.uplink.validate(cfg.frame_len * cfg.clip_frames)?;
    let period = (cfg.duty_awake + cfg.duty_sleep).max(1);
    let mut ground_truth: Vec<GroundTruthEvent> = Vec::new();
    let mut streams: Vec<SensorStream> = (0..cfg.n_streams)
        .map(|id| {
            let mut ev_rng = Pcg32::substream(cfg.seed ^ 0xeef1, id as u64);
            let events = plan_events(cfg, &mut ev_rng, id as u64);
            for ev in &events {
                ground_truth.push(GroundTruthEvent {
                    stream: id as u64,
                    class: ev.class,
                    start: ev.start,
                    end: ev.start + cfg.clip_frames as u64,
                });
            }
            let mut scfg = SessionConfig::new(id as u64, cfg.frame_len, cfg.clip_frames);
            scfg.pre_trigger_frames = cfg.pre_trigger_frames;
            scfg.gate = cfg.gate;
            scfg.duty = DutyCycle {
                awake_frames: cfg.duty_awake.max(1),
                sleep_frames: cfg.duty_sleep,
                phase: (id as u32).wrapping_mul(7) % period,
            };
            SensorStream {
                session: EdgeSession::new(scfg),
                ambient_rng: Pcg32::substream(cfg.seed, id as u64),
                events,
                next_event: 0,
                active: None,
            }
        })
        .collect();

    let frame_dur = cfg.frame_len as f64 / cfg.sample_rate;
    let clip_samples = cfg.frame_len * cfg.clip_frames;
    let mut uplink = Uplink::new(cfg.uplink);
    // (stream, clip_seq) -> onset tick, for ground-truth matching
    let mut onsets: Vec<(u64, u64, u64)> = Vec::new();
    let mut tasks: Vec<FrameTask> = Vec::new();
    let t0 = Instant::now();

    for tick in 0..cfg.ticks {
        uplink.tick(frame_dur);
        let mut awake_now = 0i64;
        for s in streams.iter_mut() {
            // a sensor mid-capture stays awake to finish its clip
            // (splicing audio from across a sleep gap would hand the
            // classifier a discontinuous clip); only Idle sensors sleep
            if !s.session.awake(tick) && s.session.state() == SessionState::Idle {
                s.session.note_asleep();
                continue;
            }
            awake_now += 1;
            let (frame, label) = s.next_frame(tick, cfg);
            uplink.record_raw(frame.len());
            tasks.clear();
            s.session.push_frame(&frame, label, &mut tasks);
            for t in tasks.drain(..) {
                if t.frame_idx == 0 {
                    onsets.push((t.stream, t.clip_seq, tick));
                }
                lane.push(t);
            }
        }
        crate::metric_gauge!("edge_streams_awake").set(awake_now);
        // classify everything that became ready within this virtual tick
        let before = lane.clips_classified();
        lane.drain()?;
        for _ in before..lane.clips_classified() {
            uplink.send_event(clip_samples);
        }
    }
    let wall = t0.elapsed();
    let (serve_report, results) = lane.finish()?;

    // ---- ground-truth matching
    let pre = cfg.pre_trigger_frames as u64;
    let mut recalled = vec![false; ground_truth.len()];
    let mut false_triggers = 0u64;
    let mut onset_match: HashMap<(u64, u64), Option<usize>> = HashMap::new();
    for &(stream, clip_seq, tick) in &onsets {
        let w0 = tick.saturating_sub(pre);
        let w1 = w0 + cfg.clip_frames as u64;
        let hit = ground_truth
            .iter()
            .position(|gt| gt.stream == stream && w0 < gt.end && gt.start < w1);
        match hit {
            Some(i) => recalled[i] = true,
            None => false_triggers += 1,
        }
        onset_match.insert((stream, clip_seq), hit);
    }
    let (mut matched_total, mut matched_correct) = (0u64, 0u64);
    for r in &results {
        if let Some(Some(gt)) = onset_match.get(&(r.stream, r.clip_seq)) {
            matched_total += 1;
            if r.predicted == ground_truth[*gt].class {
                matched_correct += 1;
            }
        }
    }

    // ---- aggregate session counters
    let mut frames_seen = 0u64;
    let mut frames_asleep = 0u64;
    let mut gated_off = 0u64;
    let mut onset_count = 0u64;
    let mut lookback_truncated = 0u64;
    let mut gate_resets = 0u64;
    for s in &streams {
        frames_seen += s.session.stats.frames_seen;
        frames_asleep += s.session.stats.frames_asleep;
        gated_off += s.session.stats.frames_gated_off;
        onset_count += s.session.stats.trigger_onsets;
        lookback_truncated += s.session.stats.lookback_truncated;
        gate_resets += s.session.stats.gate_resets;
    }

    let report = FleetReport {
        streams: cfg.n_streams,
        ticks: cfg.ticks,
        audio_seconds: frames_seen as f64 * frame_dur,
        duty_factor: frames_seen as f64 / (frames_seen + frames_asleep).max(1) as f64,
        gated_off_fraction: gated_off as f64 / frames_seen.max(1) as f64,
        trigger_onsets: onset_count,
        clips_classified: serve_report.clips_classified,
        clips_aborted: serve_report.clips_aborted,
        frames_dropped: serve_report.frames_dropped,
        lookback_truncated,
        gate_resets,
        events_total: ground_truth.len(),
        events_recalled: recalled.iter().filter(|&&r| r).count(),
        false_triggers,
        matched_total,
        matched_correct,
        uplink: uplink.stats,
        bytes_saved_ratio: uplink.bytes_saved_ratio(),
        wall,
        per_lane: serve_report.per_lane,
    };
    Ok((report, results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::multirate::BandPlan;
    use crate::runtime::backend::CpuEngine;

    fn tiny_backend() -> CpuEngine {
        let mut plan = BandPlan::paper_default();
        plan.n_octaves = 2;
        CpuEngine::with_clip(&plan, 1.0, 256, 4)
    }

    /// Single-lane pipeline over the tiny backend, fleet-configured.
    fn tiny_lane(model: &TrainedModel, cfg: &FleetConfig) -> impl Lane {
        PipelineBuilder::new(tiny_backend(), model.clone())
            .policy(cfg.policy)
            .queue_capacity(cfg.queue_capacity)
            .build()
    }

    fn dummy_model(p: usize) -> TrainedModel {
        TrainedModel::synthetic(9, 10, p, 5.0, 5.0)
    }

    fn tiny_config() -> FleetConfig {
        FleetConfig {
            n_streams: 3,
            ticks: 100,
            events_per_stream: 1,
            event_class: Some(3), // crying_baby: dense, gate-friendly
            seed: 42,
            ambient_rms: 0.02,
            event_gain: 1.0,
            frame_len: 256,
            clip_frames: 4,
            pre_trigger_frames: 1,
            duty_awake: 1,
            duty_sleep: 0,
            gate: GateConfig::default(),
            uplink: UplinkConfig::default(),
            policy: BatcherPolicy::default(),
            queue_capacity: 64,
            sample_rate: 16_000.0,
            shards: 1,
        }
    }

    #[test]
    fn fleet_detects_embedded_events_and_saves_bandwidth() {
        let model = dummy_model(tiny_backend().n_filters());
        let cfg = tiny_config();
        let (report, results) = run_fleet(tiny_lane(&model, &cfg), &cfg).unwrap();
        assert_eq!(report.events_total, 3, "{}", report.render());
        assert!(report.events_recalled >= 2, "{}", report.render());
        assert!(report.false_triggers <= 2, "{}", report.render());
        assert_eq!(report.clips_classified as usize, results.len());
        assert!(report.clips_classified >= report.events_recalled as u64);
        assert!(report.gated_off_fraction > 0.5, "{}", report.render());
        assert!(report.bytes_saved_ratio > 10.0, "{}", report.render());
        assert_eq!(report.uplink.msgs_sent, report.clips_classified);
        // report renders and tabulates without panicking
        assert!(report.render().contains("bytes-saved"));
        assert_eq!(report.table().rows.len(), 22);
    }

    #[test]
    fn sharded_fleet_matches_single_lane() {
        let model = dummy_model(tiny_backend().n_filters());
        let cfg = tiny_config();
        let (single_report, mut rs) = run_fleet(tiny_lane(&model, &cfg), &cfg).unwrap();
        let mut cfg2 = tiny_config();
        cfg2.shards = 2;
        let sharded = fleet_lane(&cfg2, model, |_| Ok(tiny_backend())).unwrap();
        let (merged_report, mut rm) = run_fleet(sharded, &cfg2).unwrap();
        // same clips classified with the same outputs, reports merge to
        // the same totals, and the lane breakdown is present
        rs.sort_by_key(|r| (r.stream, r.clip_seq));
        rm.sort_by_key(|r| (r.stream, r.clip_seq));
        assert_eq!(rs.len(), rm.len());
        for (a, b) in rs.iter().zip(&rm) {
            assert_eq!((a.stream, a.clip_seq), (b.stream, b.clip_seq));
            assert_eq!(a.predicted, b.predicted);
            assert_eq!(a.p, b.p);
        }
        assert_eq!(
            merged_report.clips_classified,
            single_report.clips_classified
        );
        assert_eq!(merged_report.trigger_onsets, single_report.trigger_onsets);
        assert_eq!(merged_report.events_recalled, single_report.events_recalled);
        assert_eq!(merged_report.per_lane.len(), 2);
        assert!(single_report.per_lane.is_empty());
        assert!(merged_report.render().contains("lanes:"));
    }

    #[test]
    fn duty_cycling_reduces_captured_audio() {
        let model = dummy_model(tiny_backend().n_filters());
        let mut cfg = tiny_config();
        cfg.duty_awake = 3;
        cfg.duty_sleep = 1;
        let (report, _) = run_fleet(tiny_lane(&model, &cfg), &cfg).unwrap();
        assert!(
            (report.duty_factor - 0.75).abs() < 0.05,
            "duty factor {}",
            report.duty_factor
        );
        assert!(report.audio_seconds < 3.0 * 100.0 * 256.0 / 16_000.0);
    }

    #[test]
    fn empty_window_plans_no_events() {
        let mut cfg = tiny_config();
        cfg.ticks = 10; // smaller than warmup + guard
        let mut rng = Pcg32::new(1);
        assert!(plan_events(&cfg, &mut rng, 0).is_empty());
    }
}
