//! Bandwidth-budgeted uplink model: a token bucket standing in for the
//! remote node's constrained link (LoRa/satellite class), plus the
//! accounting that yields the headline **bytes-saved ratio** — uplink
//! bytes actually sent vs. streaming every captured sample raw, which is
//! the paper's Fig. 1 motivation for classifying where data is produced.

/// Link budget and message sizing.
#[derive(Clone, Copy, Debug)]
pub struct UplinkConfig {
    /// sustained link budget
    pub bytes_per_sec: f64,
    /// token bucket depth (burst tolerance)
    pub burst_bytes: f64,
    /// size of one classification report (ids, class, score, timestamp)
    pub event_msg_bytes: usize,
    /// also ship the triggered clip's audio with every report
    pub upload_clips: bool,
    /// raw sample width for the "stream everything" baseline (16-bit PCM)
    pub bytes_per_sample: usize,
}

impl Default for UplinkConfig {
    fn default() -> Self {
        UplinkConfig {
            bytes_per_sec: 4096.0,
            burst_bytes: 16_384.0,
            event_msg_bytes: 32,
            upload_clips: false,
            bytes_per_sample: 2,
        }
    }
}

/// Classic token bucket in simulated time (the fleet advances it one
/// frame-duration per tick).
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_sec: f64, burst_bytes: f64) -> TokenBucket {
        TokenBucket {
            rate: rate_bytes_per_sec,
            burst: burst_bytes,
            tokens: burst_bytes,
        }
    }

    pub fn tokens(&self) -> f64 {
        self.tokens
    }

    /// Refill for `dt` seconds of simulated time.
    pub fn tick(&mut self, dt: f64) {
        self.tokens = (self.tokens + self.rate * dt).min(self.burst);
    }

    /// Take `bytes` if the budget allows it.
    pub fn try_take(&mut self, bytes: f64) -> bool {
        if bytes <= self.tokens {
            self.tokens -= bytes;
            true
        } else {
            false
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct UplinkStats {
    pub msgs_sent: u64,
    pub msgs_dropped: u64,
    pub bytes_sent: u64,
    pub bytes_dropped: u64,
    /// what streaming every captured sample raw would have cost
    pub raw_bytes_captured: u64,
}

/// The fleet's shared gateway link.
#[derive(Clone, Debug)]
pub struct Uplink {
    cfg: UplinkConfig,
    bucket: TokenBucket,
    pub stats: UplinkStats,
}

impl Uplink {
    pub fn new(cfg: UplinkConfig) -> Uplink {
        Uplink {
            cfg,
            bucket: TokenBucket::new(cfg.bytes_per_sec, cfg.burst_bytes),
            stats: UplinkStats::default(),
        }
    }

    pub fn config(&self) -> &UplinkConfig {
        &self.cfg
    }

    /// Advance simulated time.
    pub fn tick(&mut self, dt: f64) {
        self.bucket.tick(dt);
    }

    /// Account samples that the raw-streaming baseline would have sent.
    pub fn record_raw(&mut self, samples: usize) {
        self.stats.raw_bytes_captured += (samples * self.cfg.bytes_per_sample) as u64;
    }

    /// Try to send one event report (optionally with its clip audio).
    /// Returns false when the budget rejects it.
    pub fn send_event(&mut self, clip_samples: usize) -> bool {
        let mut bytes = self.cfg.event_msg_bytes;
        if self.cfg.upload_clips {
            bytes += clip_samples * self.cfg.bytes_per_sample;
        }
        if self.bucket.try_take(bytes as f64) {
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += bytes as u64;
            true
        } else {
            self.stats.msgs_dropped += 1;
            self.stats.bytes_dropped += bytes as u64;
            false
        }
    }

    /// Raw-streaming cost over what actually crossed the link.
    pub fn bytes_saved_ratio(&self) -> f64 {
        self.stats.raw_bytes_captured as f64 / (self.stats.bytes_sent.max(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_caps_at_burst_and_refills() {
        let mut b = TokenBucket::new(100.0, 50.0);
        assert!(b.try_take(50.0));
        assert!(!b.try_take(1.0));
        b.tick(0.2); // +20 bytes
        assert!(b.try_take(20.0));
        assert!(!b.try_take(0.5));
        b.tick(10.0); // refill far beyond burst: capped
        assert!((b.tokens() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn send_accounts_and_drops() {
        let cfg = UplinkConfig {
            bytes_per_sec: 0.0,
            burst_bytes: 64.0,
            event_msg_bytes: 32,
            ..UplinkConfig::default()
        };
        let mut u = Uplink::new(cfg);
        assert!(u.send_event(0));
        assert!(u.send_event(0));
        assert!(!u.send_event(0), "budget exhausted");
        assert_eq!(u.stats.msgs_sent, 2);
        assert_eq!(u.stats.msgs_dropped, 1);
        assert_eq!(u.stats.bytes_sent, 64);
        assert_eq!(u.stats.bytes_dropped, 32);
    }

    #[test]
    fn clip_upload_costs_audio_bytes() {
        let cfg = UplinkConfig {
            upload_clips: true,
            burst_bytes: 1e9,
            ..UplinkConfig::default()
        };
        let mut u = Uplink::new(cfg);
        assert!(u.send_event(1000));
        assert_eq!(u.stats.bytes_sent, 32 + 2000);
    }

    #[test]
    fn bytes_saved_ratio_vs_raw_streaming() {
        let mut u = Uplink::new(UplinkConfig::default());
        u.record_raw(16_000 * 10); // 10 s of 16 kHz 16-bit audio
        assert!(u.send_event(0));
        let ratio = u.bytes_saved_ratio();
        assert!((ratio - 320_000.0 / 32.0).abs() < 1e-9, "{ratio}");
        // no sends at all: ratio stays finite
        let empty = Uplink::new(UplinkConfig::default());
        assert_eq!(empty.bytes_saved_ratio(), 0.0);
    }
}
