//! Bandwidth-budgeted uplink model: a token bucket standing in for the
//! remote node's constrained link (LoRa/satellite class), plus the
//! accounting that yields the headline **bytes-saved ratio** — uplink
//! bytes actually sent vs. streaming every captured sample raw, which is
//! the paper's Fig. 1 motivation for classifying where data is produced.

/// Link budget and message sizing.
#[derive(Clone, Copy, Debug)]
pub struct UplinkConfig {
    /// sustained link budget
    pub bytes_per_sec: f64,
    /// token bucket depth (burst tolerance)
    pub burst_bytes: f64,
    /// size of one classification report (ids, class, score, timestamp)
    pub event_msg_bytes: usize,
    /// also ship the triggered clip's audio with every report
    pub upload_clips: bool,
    /// raw sample width for the "stream everything" baseline (16-bit PCM)
    pub bytes_per_sample: usize,
}

impl Default for UplinkConfig {
    fn default() -> Self {
        UplinkConfig {
            bytes_per_sec: 4096.0,
            burst_bytes: 16_384.0,
            event_msg_bytes: 32,
            upload_clips: false,
            bytes_per_sample: 2,
        }
    }
}

impl UplinkConfig {
    /// The largest single message this config can produce: one event
    /// report, plus the clip audio when clip upload is on.
    pub fn max_msg_bytes(&self, clip_samples: usize) -> usize {
        let mut bytes = self.event_msg_bytes;
        if self.upload_clips {
            bytes += clip_samples * self.bytes_per_sample;
        }
        bytes
    }

    /// Config-time guard against permanently unsendable messages: a
    /// token bucket can never accumulate more than `burst_bytes`, so any
    /// message larger than the burst would be dropped forever no matter
    /// how idle the link is. Callers that know their clip geometry
    /// (e.g. [`run_fleet`](crate::edge::fleet::run_fleet)) validate up
    /// front instead of discovering the black hole in the drop stats.
    pub fn validate(&self, clip_samples: usize) -> anyhow::Result<()> {
        let max = self.max_msg_bytes(clip_samples);
        anyhow::ensure!(
            max as f64 <= self.burst_bytes,
            "uplink burst ({} B) cannot hold the largest message ({} B{}); \
             raise burst_bytes or disable clip upload",
            self.burst_bytes,
            max,
            if self.upload_clips {
                " with clip upload on"
            } else {
                ""
            }
        );
        Ok(())
    }
}

/// Classic token bucket in simulated time (the fleet advances it one
/// frame-duration per tick).
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_sec: f64, burst_bytes: f64) -> TokenBucket {
        TokenBucket {
            rate: rate_bytes_per_sec,
            burst: burst_bytes,
            tokens: burst_bytes,
        }
    }

    pub fn tokens(&self) -> f64 {
        self.tokens
    }

    /// Bucket depth: the hard ceiling on any single take.
    pub fn burst(&self) -> f64 {
        self.burst
    }

    /// Refill for `dt` seconds of simulated time.
    pub fn tick(&mut self, dt: f64) {
        self.tokens = (self.tokens + self.rate * dt).min(self.burst);
    }

    /// Take `bytes` if the budget allows it.
    pub fn try_take(&mut self, bytes: f64) -> bool {
        if bytes <= self.tokens {
            self.tokens -= bytes;
            true
        } else {
            false
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct UplinkStats {
    pub msgs_sent: u64,
    /// budget drops: the bucket will refill and later messages can pass
    pub msgs_dropped: u64,
    /// messages larger than the bucket's burst — these can *never* be
    /// sent under this config, which is a sizing bug, not congestion,
    /// and is accounted separately so it cannot hide among budget drops
    pub msgs_oversized: u64,
    pub bytes_sent: u64,
    pub bytes_dropped: u64,
    /// what streaming every captured sample raw would have cost
    pub raw_bytes_captured: u64,
}

/// The fleet's shared gateway link.
#[derive(Clone, Debug)]
pub struct Uplink {
    cfg: UplinkConfig,
    bucket: TokenBucket,
    pub stats: UplinkStats,
}

impl Uplink {
    pub fn new(cfg: UplinkConfig) -> Uplink {
        Uplink {
            cfg,
            bucket: TokenBucket::new(cfg.bytes_per_sec, cfg.burst_bytes),
            stats: UplinkStats::default(),
        }
    }

    pub fn config(&self) -> &UplinkConfig {
        &self.cfg
    }

    /// Advance simulated time.
    pub fn tick(&mut self, dt: f64) {
        self.bucket.tick(dt);
    }

    /// Account samples that the raw-streaming baseline would have sent.
    pub fn record_raw(&mut self, samples: usize) {
        self.stats.raw_bytes_captured += (samples * self.cfg.bytes_per_sample) as u64;
    }

    /// Try to send one event report (optionally with its clip audio).
    /// Returns false when the budget rejects it. A message larger than
    /// the bucket's burst can never pass [`TokenBucket::try_take`]
    /// (tokens are capped at the burst), so it is accounted as
    /// `msgs_oversized` — a config-sizing bug — rather than blending
    /// into the budget drops and silently black-holing every clip report.
    pub fn send_event(&mut self, clip_samples: usize) -> bool {
        let bytes = self.cfg.max_msg_bytes(clip_samples);
        if bytes as f64 > self.bucket.burst() {
            self.stats.msgs_oversized += 1;
            self.stats.bytes_dropped += bytes as u64;
            crate::metric_counter!("edge_uplink_oversized_total").inc();
            return false;
        }
        if self.bucket.try_take(bytes as f64) {
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += bytes as u64;
            crate::metric_counter!("edge_uplink_msgs_total").inc();
            crate::metric_counter!("edge_uplink_bytes_total").add(bytes as u64);
            true
        } else {
            self.stats.msgs_dropped += 1;
            self.stats.bytes_dropped += bytes as u64;
            crate::metric_counter!("edge_uplink_drops_total").inc();
            false
        }
    }

    /// Raw-streaming cost over what actually crossed the link.
    pub fn bytes_saved_ratio(&self) -> f64 {
        self.stats.raw_bytes_captured as f64 / (self.stats.bytes_sent.max(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_caps_at_burst_and_refills() {
        let mut b = TokenBucket::new(100.0, 50.0);
        assert!(b.try_take(50.0));
        assert!(!b.try_take(1.0));
        b.tick(0.2); // +20 bytes
        assert!(b.try_take(20.0));
        assert!(!b.try_take(0.5));
        b.tick(10.0); // refill far beyond burst: capped
        assert!((b.tokens() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn send_accounts_and_drops() {
        let cfg = UplinkConfig {
            bytes_per_sec: 0.0,
            burst_bytes: 64.0,
            event_msg_bytes: 32,
            ..UplinkConfig::default()
        };
        let mut u = Uplink::new(cfg);
        assert!(u.send_event(0));
        assert!(u.send_event(0));
        assert!(!u.send_event(0), "budget exhausted");
        assert_eq!(u.stats.msgs_sent, 2);
        assert_eq!(u.stats.msgs_dropped, 1);
        assert_eq!(u.stats.bytes_sent, 64);
        assert_eq!(u.stats.bytes_dropped, 32);
    }

    #[test]
    fn clip_upload_costs_audio_bytes() {
        let cfg = UplinkConfig {
            upload_clips: true,
            burst_bytes: 1e9,
            ..UplinkConfig::default()
        };
        let mut u = Uplink::new(cfg);
        assert!(u.send_event(1000));
        assert_eq!(u.stats.bytes_sent, 32 + 2000);
    }

    #[test]
    fn oversized_message_counts_as_oversized_not_dropped() {
        // a clip report bigger than the burst can never pass try_take no
        // matter how long the bucket refills — it must be accounted as a
        // sizing bug, while plain event reports keep flowing
        let cfg = UplinkConfig {
            bytes_per_sec: 1e9,
            burst_bytes: 256.0,
            event_msg_bytes: 32,
            upload_clips: true,
            bytes_per_sample: 2,
        };
        let mut u = Uplink::new(cfg);
        // 1000-sample clip -> 32 + 2000 B > 256 B burst: oversized forever
        for _ in 0..3 {
            u.tick(10.0); // plenty of refill time changes nothing
            assert!(!u.send_event(1000));
        }
        assert_eq!(u.stats.msgs_oversized, 3);
        assert_eq!(u.stats.msgs_dropped, 0, "not a budget drop");
        assert_eq!(u.stats.msgs_sent, 0);
        // a bare event report (32 B, no clip) still fits the same bucket
        let mut small = Uplink::new(UplinkConfig {
            upload_clips: false,
            ..cfg
        });
        assert!(small.send_event(1000));
        assert_eq!(small.stats.msgs_oversized, 0);
    }

    #[test]
    fn validate_rejects_unsendable_configs_at_config_time() {
        let cfg = UplinkConfig {
            burst_bytes: 256.0,
            upload_clips: true,
            ..UplinkConfig::default()
        };
        let err = cfg.validate(1000).unwrap_err();
        assert!(format!("{err:#}").contains("burst"), "{err:#}");
        // same geometry with a burst grown to hold one clip message: ok
        let ok = UplinkConfig {
            burst_bytes: cfg.max_msg_bytes(1000) as f64,
            ..cfg
        };
        ok.validate(1000).unwrap();
        // clip upload off: the clip size is irrelevant
        UplinkConfig::default().validate(1_000_000).unwrap();
    }

    #[test]
    fn bytes_saved_ratio_vs_raw_streaming() {
        let mut u = Uplink::new(UplinkConfig::default());
        u.record_raw(16_000 * 10); // 10 s of 16 kHz 16-bit audio
        assert!(u.send_event(0));
        let ratio = u.bytes_saved_ratio();
        assert!((ratio - 320_000.0 / 32.0).abs() < 1e-9, "{ratio}");
        // no sends at all: ratio stays finite
        let empty = Uplink::new(UplinkConfig::default());
        assert_eq!(empty.bytes_saved_ratio(), 0.0);
    }
}
