//! Multiplierless event-activity gate — the detection front end that
//! decides, frame by frame, whether continuous sensor audio contains an
//! acoustic event worth classifying.
//!
//! The gate is built from exactly the primitives the paper's FPGA
//! datapath provides (§IV): additions, subtractions, comparisons and
//! arithmetic shifts over [`QFormat`] fixed-point values. There is no
//! multiply anywhere on the per-sample path:
//!
//! * rectified level `a = |x_q|` (negate-on-sign, no squaring),
//! * a fast envelope via a shift-based exponential average; the
//!   accumulator keeps `shift` extra fraction bits
//!   (`acc += a - (acc >> shift)`) so truncation cannot stall the
//!   integrator — the classic fixed-point leaky-integrator form,
//! * a noise floor via a slower EMA that only adapts while the gate is
//!   shut (so events do not poison the floor),
//! * a hysteresis comparator whose margins are shifts of the floor
//!   (`floor >> margin_shift` = a power-of-two relative threshold),
//! * a hangover counter that keeps the gate open for a few frames after
//!   the level falls back, bridging intra-event pauses,
//! * a warmup counter that suppresses triggering until the floor EMA has
//!   had time to converge after power-on (cold-start protection).
//!
//! Quantisation (the ADC model) happens once at [`EnergyGate::quantize`];
//! everything after is `i64` arithmetic, which the unit tests pin down by
//! showing the decision is a function of the quantised values only.

use crate::fixed::q::QFormat;

/// Gate tuning. All thresholds are expressed as shifts so the hardware
/// realisation needs no multiplier.
#[derive(Clone, Copy, Debug)]
pub struct GateConfig {
    /// input quantisation (ADC): 12-bit signed covering [-1, 1)
    pub fmt: QFormat,
    /// fast-envelope EMA shift (2^n samples time constant)
    pub fast_shift: u32,
    /// noise-floor EMA shift (much slower than `fast_shift`)
    pub slow_shift: u32,
    /// trigger margin: open when `fast > slow + (slow >> margin_shift) + floor`
    pub margin_shift: u32,
    /// release margin (a weaker condition: `release_shift > margin_shift`)
    pub release_shift: u32,
    /// absolute floor in LSBs, so dead-silent inputs cannot trigger
    pub floor_lsb: i64,
    /// frames the gate stays open after the release condition fails
    pub hangover_frames: u32,
    /// frames after power-on during which the gate cannot trigger while
    /// the noise floor converges
    pub warmup_frames: u32,
}

impl Default for GateConfig {
    fn default() -> Self {
        GateConfig {
            fmt: QFormat::new(12, 11),
            fast_shift: 5,    // ~32 samples (2 ms at 16 kHz)
            slow_shift: 11,   // ~2048 samples (128 ms)
            margin_shift: 1,  // trigger at floor + 50 %
            release_shift: 2, // release below floor + 25 %
            floor_lsb: 8,
            hangover_frames: 1,
            // warmup is counted in frames, so pick it for the shortest
            // frames in use (256 samples): 24 frames = 3 floor time
            // constants; long-frame callers (2048 samples) override down
            warmup_frames: 24,
        }
    }
}

/// Per-frame gate verdict.
#[derive(Clone, Copy, Debug)]
pub struct GateFrame {
    /// gate state after this frame
    pub open: bool,
    /// this frame opened the gate (detection onset)
    pub onset: bool,
    /// this frame closed the gate
    pub offset: bool,
    /// fast envelope at frame end (input LSBs)
    pub fast: i64,
    /// noise floor at frame end (input LSBs)
    pub slow: i64,
}

/// The streaming gate. One per sensor stream; a few registers of state.
#[derive(Clone, Debug)]
pub struct EnergyGate {
    cfg: GateConfig,
    /// fast EMA accumulator, `fast_shift` extra fraction bits
    acc_fast: i64,
    /// floor EMA accumulator, `slow_shift` extra fraction bits
    acc_slow: i64,
    open: bool,
    hangover: u32,
    warmup: u32,
}

impl EnergyGate {
    pub fn new(cfg: GateConfig) -> EnergyGate {
        assert!(
            cfg.release_shift > cfg.margin_shift,
            "hysteresis needs release margin < trigger margin"
        );
        EnergyGate {
            cfg,
            acc_fast: 0,
            acc_slow: 0,
            open: false,
            hangover: 0,
            warmup: cfg.warmup_frames,
        }
    }

    pub fn config(&self) -> &GateConfig {
        &self.cfg
    }

    pub fn is_open(&self) -> bool {
        self.open
    }

    /// Fast envelope in input LSBs.
    pub fn fast(&self) -> i64 {
        self.acc_fast >> self.cfg.fast_shift
    }

    /// Noise floor in input LSBs.
    pub fn slow(&self) -> i64 {
        self.acc_slow >> self.cfg.slow_shift
    }

    /// ADC model: quantise a float frame into gate LSBs. This is the only
    /// place floats appear; the returned values feed the integer path.
    pub fn quantize(&self, frame: &[f32]) -> Vec<i64> {
        self.cfg.fmt.quantize_vec(frame)
    }

    /// Advance the envelopes over one quantised frame and evaluate the
    /// hysteresis comparator at the frame boundary. Integer-only.
    pub fn push_frame(&mut self, frame_q: &[i64]) -> GateFrame {
        let was_open = self.open;
        for &q in frame_q {
            // |x|: negate-on-sign, no multiply
            let a = if q < 0 { -q } else { q };
            self.acc_fast += a - (self.acc_fast >> self.cfg.fast_shift);
            if !self.open {
                self.acc_slow += a - (self.acc_slow >> self.cfg.slow_shift);
            }
        }
        let fast = self.fast();
        let slow = self.slow();
        let trigger = fast > slow + (slow >> self.cfg.margin_shift) + self.cfg.floor_lsb;
        let sustain = fast > slow + (slow >> self.cfg.release_shift) + self.cfg.floor_lsb;
        if self.warmup > 0 {
            self.warmup -= 1;
        } else if self.open {
            if sustain {
                self.hangover = self.cfg.hangover_frames;
            } else if self.hangover > 0 {
                self.hangover -= 1;
            } else {
                self.open = false;
            }
        } else if trigger {
            self.open = true;
            self.hangover = self.cfg.hangover_frames;
        }
        GateFrame {
            open: self.open,
            onset: self.open && !was_open,
            offset: was_open && !self.open,
            fast,
            slow,
        }
    }

    /// Back to power-on state (warmup included).
    pub fn reset(&mut self) {
        self.acc_fast = 0;
        self.acc_slow = 0;
        self.open = false;
        self.hangover = 0;
        self.warmup = self.cfg.warmup_frames;
    }

    /// Test/experiment hook: a gate with a fully converged floor at
    /// `level` LSBs, warmup already elapsed.
    pub fn with_converged_floor(cfg: GateConfig, level: i64, open: bool) -> EnergyGate {
        EnergyGate {
            cfg,
            acc_fast: level << cfg.fast_shift,
            acc_slow: level << cfg.slow_shift,
            open,
            hangover: if open { cfg.hangover_frames } else { 0 },
            warmup: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    const FRAME: usize = 256;

    /// Deterministic ambient: a ±amp square wave, so the rectified level
    /// is exactly `round(amp / lsb)` and the EMAs converge exactly.
    fn square(gate: &EnergyGate, amp: f32) -> Vec<i64> {
        let frame: Vec<f32> = (0..FRAME)
            .map(|i| if i % 2 == 0 { amp } else { -amp })
            .collect();
        gate.quantize(&frame)
    }

    fn noise(gate: &EnergyGate, amp: f32, seed: u64) -> Vec<i64> {
        let mut rng = crate::util::prng::Pcg32::new(seed);
        let frame: Vec<f32> = (0..FRAME).map(|_| (rng.normal() as f32) * amp).collect();
        gate.quantize(&frame)
    }

    /// Settle the floor on deterministic ambient, then return the gate.
    fn settled(amp: f32) -> EnergyGate {
        let mut g = EnergyGate::new(GateConfig::default());
        let q = square(&g, amp);
        for _ in 0..64 {
            g.push_frame(&q);
        }
        assert!(!g.is_open(), "gate must not open on steady ambient");
        g
    }

    #[test]
    fn settling_converges_exactly_on_dc_level() {
        let g = settled(0.02);
        let a = g.config().fmt.quantize(0.02);
        assert_eq!(g.fast(), a);
        assert_eq!(g.slow(), a);
    }

    #[test]
    fn triggers_on_burst_and_releases_after_hangover() {
        let mut g = settled(0.02);
        let f = g.push_frame(&square(&g, 0.4));
        assert!(f.open && f.onset, "{f:?}");
        // back to ambient: sustain fails, hangover (1 frame) then close
        let f1 = g.push_frame(&noise(&g, 0.02, 99));
        assert!(f1.open && !f1.onset, "hangover keeps the gate open: {f1:?}");
        let f2 = g.push_frame(&noise(&g, 0.02, 100));
        assert!(!f2.open && f2.offset, "{f2:?}");
    }

    #[test]
    fn silence_never_triggers() {
        let mut g = EnergyGate::new(GateConfig::default());
        let zeros = [0i64; FRAME];
        for _ in 0..50 {
            let f = g.push_frame(&zeros);
            assert!(!f.open);
        }
    }

    #[test]
    fn cold_start_on_ambient_does_not_latch_open() {
        // without warmup, the first frames would compare a converged fast
        // envelope against a still-rising floor and latch the gate open
        check("vad-cold-start", 20, |gen| {
            let amp = gen.f64(0.01, 0.08) as f32;
            let mut g = EnergyGate::new(GateConfig::default());
            for i in 0..48 {
                g.push_frame(&noise(&g, amp, 500 + i));
            }
            assert!(!g.is_open(), "latched open on ambient amp {amp}");
        });
    }

    #[test]
    fn decision_depends_only_on_quantised_values() {
        // sub-LSB float perturbations are invisible after the ADC: the
        // integer path cannot distinguish them (no hidden float state)
        let g0 = EnergyGate::new(GateConfig::default());
        let lsb = g0.config().fmt.lsb() as f32;
        let mut a = EnergyGate::new(GateConfig::default());
        let mut b = EnergyGate::new(GateConfig::default());
        let mut rng = crate::util::prng::Pcg32::new(3);
        for _ in 0..30 {
            let frame: Vec<f32> = (0..FRAME).map(|_| (rng.normal() as f32) * 0.1).collect();
            let qa = a.quantize(&frame);
            // re-quantise a sub-LSB perturbation away from any midpoint
            let perturbed: Vec<f32> = frame
                .iter()
                .map(|&x| {
                    let q = g0.config().fmt.quantize_f32(x);
                    g0.config().fmt.dequantize(q) as f32 + 0.2 * lsb
                })
                .collect();
            let qb = b.quantize(&perturbed);
            assert_eq!(qa, qb, "quantisation must absorb sub-LSB noise");
            let fa = a.push_frame(&qa);
            let fb = b.push_frame(&qb);
            assert_eq!(fa.open, fb.open);
            assert_eq!(fa.fast, fb.fast);
            assert_eq!(fa.slow, fb.slow);
        }
    }

    #[test]
    fn hysteresis_band_sustains_but_never_triggers() {
        // a level strictly between the release and trigger thresholds
        // must sustain an open gate yet never open a closed one
        check("vad-hysteresis", 40, |gen| {
            let cfg = GateConfig::default();
            let floor = gen.int(20, 400);
            let trigger_at = floor + (floor >> cfg.margin_shift) + cfg.floor_lsb;
            let release_at = floor + (floor >> cfg.release_shift) + cfg.floor_lsb;
            let mid = (release_at + trigger_at) / 2 + 1;
            if mid >= trigger_at {
                return; // thresholds too close at this floor to separate
            }
            let frame = [mid; FRAME];
            // closed gate: the floor drifts up toward mid, which only
            // raises the trigger threshold — must stay closed
            let mut closed = EnergyGate::with_converged_floor(cfg, floor, false);
            for _ in 0..6 {
                assert!(
                    !closed.push_frame(&frame).open,
                    "triggered inside the hysteresis band (floor {floor})"
                );
            }
            // open gate: the floor is frozen, the same level sustains
            let mut open = EnergyGate::with_converged_floor(cfg, floor, true);
            for _ in 0..6 {
                assert!(
                    open.push_frame(&frame).open,
                    "released inside the hysteresis band (floor {floor})"
                );
            }
        });
    }

    #[test]
    fn hangover_counts_full_frames() {
        let cfg = GateConfig {
            hangover_frames: 3,
            ..GateConfig::default()
        };
        let mut g = EnergyGate::with_converged_floor(cfg, 40, false);
        let f = g.push_frame(&[400i64; FRAME]);
        assert!(f.open && f.onset);
        let quiet = [40i64; FRAME];
        let mut open_frames = 0;
        for _ in 0..10 {
            if g.push_frame(&quiet).open {
                open_frames += 1;
            } else {
                break;
            }
        }
        assert_eq!(open_frames, 3, "hangover must hold exactly 3 frames");
    }

    #[test]
    fn floor_tracks_a_moderately_raised_ambient() {
        // +20 % ambient sits under the +50 % trigger margin: the floor
        // follows and the gate never opens
        let mut g = EnergyGate::with_converged_floor(GateConfig::default(), 20, false);
        let frame = [24i64; FRAME];
        for _ in 0..40 {
            assert!(!g.push_frame(&frame).open);
        }
        assert!(g.slow() >= 23, "floor failed to track: {}", g.slow());
        assert_eq!(g.fast(), 24);
    }

    #[test]
    fn reset_restores_warmup() {
        let mut g = settled(0.02);
        g.push_frame(&square(&g, 0.4));
        assert!(g.is_open());
        g.reset();
        assert!(!g.is_open());
        assert_eq!(g.fast(), 0);
        // first post-reset frames cannot trigger (warmup)
        let f = g.push_frame(&square(&g, 0.4));
        assert!(!f.open);
    }
}
