//! Baseline-system benches: SMO SVM training/prediction and the CAR-IHC
//! cascade — the comparison costs behind Tables II-IV.

use infilter::bench_util::Bench;
use infilter::carihc::CarIhc;
use infilter::svm::{self, Kernel, SmoConfig};
use infilter::util::prng::Pcg32;

fn main() {
    let mut b = Bench::new("bench_baselines");
    let mut rng = Pcg32::new(5);

    // SVM on 30-dim features
    let n = 200;
    let xs: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let c = if i % 2 == 0 { 1.0 } else { -1.0 };
            (0..30).map(|_| (c + rng.normal() * 0.8) as f32).collect()
        })
        .collect();
    let ys: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
    let kernel = Kernel::Rbf { gamma: 0.05 };
    b.run("svm/smo_train/n200_d30", || {
        svm::train(&xs, &ys, kernel, &SmoConfig::default())
    });
    let model = svm::train(&xs, &ys, kernel, &SmoConfig::default());
    b.run("svm/predict/d30", || model.predict(&xs[0]));

    // CAR-IHC cascade over a 1 s clip
    let clip: Vec<f32> = rng.normal_vec(16384).iter().map(|x| 0.25 * x).collect();
    let mut car = CarIhc::paper_default();
    b.run_with_throughput("carihc/features_clip16384", Some((1.024, "audio_s")), || {
        car.features(&clip)
    });
    b.finish();
}
