//! Frame-feature path benches: the coordinator hot path (the shared MP
//! kernel in b1 and true-b8 form, the verbatim sort-based reference it
//! replaced, HLO b1 vs b8 when artifacts exist), the rust float MP bank,
//! the conventional FIR bank and the direct high-order bank (Fig. 4 cost
//! story).
//!
//! Run with `-- --json` to record the trajectory in
//! `BENCH_filterbank.json` (see bench_util): the acceptance ratio of the
//! kernel PR is `bank/rust_mp_kernel/frame2048` vs
//! `bank/rust_mp_exact_sort/frame2048`, and `bank/rust_mp_kernel_b8`'s
//! audio_s/s must beat the b1 case's (its iteration already covers 8x
//! the audio, so higher audio_s/s = faster than 8 sequential b1 calls).

use infilter::bench_util::Bench;
use infilter::dsp::multirate::{BandPlan, MultirateFirBank};
use infilter::features;
use infilter::mp::filter::MpMultirateBank;
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::runtime::engine::ModelEngine;
use infilter::util::prng::Pcg32;
use std::path::Path;

fn main() {
    let mut b = Bench::new("bench_filterbank");
    let plan = BandPlan::paper_default();
    let mut rng = Pcg32::new(2);
    let frame: Vec<f32> = rng.normal_vec(2048).iter().map(|x| 0.3 * x).collect();
    let audio_s = 2048.0 / plan.sample_rate; // 128 ms per frame

    // rust banks, one 2048-sample frame
    let mut fir = MultirateFirBank::new(&plan);
    b.run_with_throughput("bank/rust_fir_multirate/frame2048", Some((audio_s, "audio_s")), || {
        fir.process(&frame)
    });
    let mut mp = MpMultirateBank::new(&plan, 1.0);
    b.run_with_throughput("bank/rust_mp_float/frame2048", Some((audio_s, "audio_s")), || {
        mp.process(&frame)
    });
    b.run("bank/rust_direct_orders15to200/frame2048", || {
        features::direct_features(&plan, &frame)
    });

    // the serving hot path: shared block kernel (new) vs the verbatim
    // sort-based reference it replaced (old) — the PR 3 headline ratio
    let mut eng = CpuEngine::new(&plan, 1.0);
    let p = eng.n_filters();
    let mut state = eng.zero_state();
    let mut phi = vec![0.0f32; p];
    b.run_with_throughput("bank/rust_mp_kernel/frame2048", Some((audio_s, "audio_s")), || {
        eng.mp_frame_features_into(&mut state, &frame, &mut phi).unwrap()
    });
    let eng_ref = CpuEngine::new(&plan, 1.0);
    let mut state_ref = eng_ref.zero_state();
    b.run_with_throughput(
        "bank/rust_mp_exact_sort/frame2048",
        Some((audio_s, "audio_s")),
        || eng_ref.frame_features_exact(&mut state_ref, &frame),
    );

    // true b8: 8 streams through one interleaved cascade; beating
    // 8x the b1 number is the batching win
    let frames8: Vec<Vec<f32>> = (0..8)
        .map(|_| rng.normal_vec(2048).iter().map(|x| 0.3 * x).collect())
        .collect();
    let refs8: Vec<&[f32]> = frames8.iter().map(Vec::as_slice).collect();
    let mut states8: Vec<_> = (0..8).map(|_| eng.zero_state()).collect();
    let mut phi8 = vec![0.0f32; 8 * p];
    b.run_with_throughput(
        "bank/rust_mp_kernel_b8/8x_frame2048",
        Some((8.0 * audio_s, "audio_s")),
        || {
            eng.mp_frame_features_b8_into(&mut states8, &refs8, &mut phi8)
                .unwrap()
        },
    );

    // HLO paths
    if Path::new("artifacts/manifest.json").exists() {
        let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0).unwrap();
        let mut st = eng.zero_state();
        eng.mp_frame_features(&mut st, &frame).unwrap(); // warm compile
        b.run_with_throughput("bank/hlo_b1/frame2048", Some((audio_s, "audio_s")), || {
            eng.mp_frame_features(&mut st, &frame).unwrap()
        });
        let mut states: Vec<_> = (0..8).map(|_| eng.zero_state()).collect();
        let frames: Vec<&[f32]> = (0..8).map(|_| frame.as_slice()).collect();
        eng.mp_frame_features_b8(&mut states, &frames).unwrap();
        b.run_with_throughput(
            "bank/hlo_b8/8x_frame2048",
            Some((8.0 * audio_s, "audio_s")),
            || eng.mp_frame_features_b8(&mut states, &frames).unwrap(),
        );
        // conventional-FIR HLO baseline
        let mut st2 = eng.zero_state();
        eng.fir_frame_features(&mut st2, &frame).unwrap();
        b.run_with_throughput("bank/hlo_fir_b1/frame2048", Some((audio_s, "audio_s")), || {
            eng.fir_frame_features(&mut st2, &frame).unwrap()
        });
    }
    b.finish();
}
