//! Frame-feature path benches: the coordinator hot path (HLO b1 vs b8 —
//! the dynamic-batcher crossover), the rust float MP bank, the
//! conventional FIR bank and the direct high-order bank (Fig. 4 cost
//! story).

use infilter::bench_util::Bench;
use infilter::dsp::multirate::{BandPlan, MultirateFirBank};
use infilter::features;
use infilter::mp::filter::MpMultirateBank;
use infilter::runtime::engine::ModelEngine;
use infilter::util::prng::Pcg32;
use std::path::Path;

fn main() {
    let mut b = Bench::new("bench_filterbank");
    let plan = BandPlan::paper_default();
    let mut rng = Pcg32::new(2);
    let frame: Vec<f32> = rng.normal_vec(2048).iter().map(|x| 0.3 * x).collect();

    // rust banks, one 2048-sample frame (128 ms of audio)
    let mut fir = MultirateFirBank::new(&plan);
    b.run_with_throughput("bank/rust_fir_multirate/frame2048", Some((0.128, "audio_s")), || {
        fir.process(&frame)
    });
    let mut mp = MpMultirateBank::new(&plan, 1.0);
    b.run_with_throughput("bank/rust_mp_float/frame2048", Some((0.128, "audio_s")), || {
        mp.process(&frame)
    });
    b.run("bank/rust_direct_orders15to200/frame2048", || {
        features::direct_features(&plan, &frame)
    });

    // HLO paths
    if Path::new("artifacts/manifest.json").exists() {
        let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0).unwrap();
        let mut st = eng.zero_state();
        eng.mp_frame_features(&mut st, &frame).unwrap(); // warm compile
        b.run_with_throughput("bank/hlo_b1/frame2048", Some((0.128, "audio_s")), || {
            eng.mp_frame_features(&mut st, &frame).unwrap()
        });
        let mut states: Vec<_> = (0..8).map(|_| eng.zero_state()).collect();
        let frames: Vec<&[f32]> = (0..8).map(|_| frame.as_slice()).collect();
        eng.mp_frame_features_b8(&mut states, &frames).unwrap();
        b.run_with_throughput(
            "bank/hlo_b8/8x_frame2048",
            Some((8.0 * 0.128, "audio_s")),
            || eng.mp_frame_features_b8(&mut states, &frames).unwrap(),
        );
        // conventional-FIR HLO baseline
        let mut st2 = eng.zero_state();
        eng.fir_frame_features(&mut st2, &frame).unwrap();
        b.run_with_throughput("bank/hlo_fir_b1/frame2048", Some((0.128, "audio_s")), || {
            eng.fir_frame_features(&mut st2, &frame).unwrap()
        });
    }
    b.finish();
}
