//! Dispatch-layer benches: single-lane `Pipeline` push→tick→classify
//! cost, and sharded throughput at 1/2/4 lanes over the same synthetic
//! multi-stream workload (JSONL via `bench_util`, like every bench).
//!
//! The sharded cases measure a full run each — spawn lanes, route the
//! whole workload, barrier, merge — so the number includes thread and
//! channel overhead, which is exactly the crossover the `--shards` flag
//! trades against.

use infilter::bench_util::Bench;
use infilter::coordinator::{
    BatcherPolicy, FrameTask, Lane, PipelineBuilder, ShardedPipeline,
};
use infilter::dsp::multirate::BandPlan;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::mp::filter::MpMultirateBank;
use infilter::net::node::pipeline_factory;
use infilter::net::{
    serve_node, NodeConfig, RemoteConfig, RemoteLane, RemotePool, WireFormat,
};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::runtime::fixed::FixedEngine;
use infilter::telemetry::registry;
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::time::Instant;

const FRAME_LEN: usize = 256;
const CLIP_FRAMES: usize = 4;
const N_STREAMS: u64 = 16;
const CLIPS_PER_STREAM: u64 = 2;

fn engine() -> CpuEngine {
    // reduced plan keeps a full fleet run inside a bench sample
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 3;
    CpuEngine::with_clip(&plan, 1.0, FRAME_LEN, CLIP_FRAMES)
}

fn model(p: usize) -> TrainedModel {
    TrainedModel::synthetic(9, 10, p, 5.0, 5.0)
}

/// Deterministic multi-stream workload, rebuilt per run.
fn workload() -> Vec<FrameTask> {
    let mut out = Vec::new();
    for s in 0..N_STREAMS {
        let mut rng = Pcg32::substream(17, s);
        for clip in 0..CLIPS_PER_STREAM {
            for f in 0..CLIP_FRAMES {
                out.push(FrameTask {
                    stream: s,
                    clip_seq: clip,
                    frame_idx: f,
                    data: (0..FRAME_LEN).map(|_| (rng.normal() * 0.1) as f32).collect(),
                    label: (s % 10) as usize,
                    t_gen: Instant::now(),
                });
            }
        }
    }
    out
}

/// The integer serving backend over the same geometry: the synthetic
/// model's float params/standardiser quantised through the certified
/// fixed-point pipeline (construction sits outside the timed region,
/// like engine()).
fn fixed_engine(m: &TrainedModel) -> FixedEngine {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 3;
    let mut bank = MpMultirateBank::new(&plan, m.gamma_f);
    let phis: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            bank.reset();
            let clip: Vec<f32> = Pcg32::new(100 + i)
                .normal_vec(FRAME_LEN * CLIP_FRAMES)
                .iter()
                .map(|x| 0.3 * x)
                .collect();
            bank.features(&clip)
        })
        .collect();
    let pipe = FixedPipeline::build(
        &plan,
        m.gamma_f,
        m.gamma_1,
        &m.params,
        &m.std,
        &phis,
        FixedConfig::with_bits(10),
    );
    FixedEngine::new(pipe, FRAME_LEN, CLIP_FRAMES, 24).expect("bench config certifies")
}

/// Smooth-tone workload for the wire-bandwidth comparison: the v4
/// delta codec's best case (tiny second-order residuals), matching the
/// acoustic frames a deployed gateway actually ships.
fn tone_workload() -> Vec<FrameTask> {
    let mut out = Vec::new();
    for s in 0..N_STREAMS {
        for clip in 0..CLIPS_PER_STREAM {
            for f in 0..CLIP_FRAMES {
                let base = (clip as usize * CLIP_FRAMES + f) * FRAME_LEN;
                out.push(FrameTask {
                    stream: s,
                    clip_seq: clip,
                    frame_idx: f,
                    data: (0..FRAME_LEN)
                        .map(|i| {
                            let t = (base + i) as f64;
                            (0.25 * (2.0 * std::f64::consts::PI * 200.0 * t / 16_000.0).sin())
                                as f32
                        })
                        .collect(),
                    label: (s % 10) as usize,
                    t_gen: Instant::now(),
                });
            }
        }
    }
    out
}

fn main() {
    let mut b = Bench::new("bench_dispatch");
    let total_clips = (N_STREAMS * CLIPS_PER_STREAM) as u64;
    // engine construction (filter-bank coefficients) and workload
    // synthesis stay outside the timed closures — the measured region
    // is push → dispatch → classify (+ lane spawn for the sharded
    // cases, which is part of what --shards trades against)
    let eng = engine();
    let m = model(eng.n_filters());
    let tasks = workload();

    // single owned lane, synchronous
    {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        b.run_with_throughput(
            "dispatch/pipeline_1lane",
            Some((total_clips as f64, "clips")),
            || {
                let mut lane = PipelineBuilder::new(eng.clone(), m.clone())
                    .queue_capacity(64)
                    .build();
                for t in tasks.clone() {
                    lane.push(t);
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish();
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // the same single lane with the telemetry kill switch thrown: the
    // delta against pipeline_1lane is the whole live-metrics tax on the
    // hot path (cached handles + relaxed atomics), guarded here so an
    // instrumentation regression shows up as a ratio, not a vibe
    {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        b.run_with_throughput(
            "dispatch/pipeline_1lane_telemetry_off",
            Some((total_clips as f64, "clips")),
            || {
                infilter::telemetry::set_enabled(false);
                let mut lane = PipelineBuilder::new(eng.clone(), m.clone())
                    .queue_capacity(64)
                    .build();
                for t in tasks.clone() {
                    lane.push(t);
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish();
                infilter::telemetry::set_enabled(true);
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // single lane again, wide-always: the same workload through the
    // true-b8 interleaved kernel (16 streams ready -> full lanes); the
    // narrow-vs-wide ratio here is the CPU batching crossover
    {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        b.run_with_throughput(
            "dispatch/pipeline_1lane_wide8",
            Some((total_clips as f64, "clips")),
            || {
                let mut lane = PipelineBuilder::new(eng.clone(), m.clone())
                    .policy(BatcherPolicy { wide_threshold: 1 })
                    .queue_capacity(64)
                    .build();
                for t in tasks.clone() {
                    lane.push(t);
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish();
                assert_eq!(report.clips_classified, total_clips);
                assert!(report.batch.wide_dispatches > 0);
                report.clips_classified
            },
        );
    }

    // the same single lane hosting the integer FixedEngine instead of
    // the float CpuEngine: the ratio against pipeline_1lane is the
    // serving cost of the certified fixed-point datapath
    {
        let (m, tasks) = (m.clone(), tasks.clone());
        let feng = fixed_engine(&m);
        b.run_with_throughput(
            "dispatch/pipeline_1lane_fixed",
            Some((total_clips as f64, "clips")),
            || {
                let mut lane = PipelineBuilder::new(feng.clone(), m.clone())
                    .queue_capacity(64)
                    .build();
                for t in tasks.clone() {
                    lane.push(t);
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish();
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // the same workload through a loopback TCP node: connect + credit
    // flow + frame serialisation + drain barrier + report — the whole
    // cross-process tax relative to pipeline_1lane, tracked from day one
    {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        let fp = m.fingerprint();
        b.run_with_throughput(
            "dispatch/remote_1node",
            Some((total_clips as f64, "clips")),
            || {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap().to_string();
                let (eng, m) = (eng.clone(), m.clone());
                let node = std::thread::spawn(move || {
                    serve_node(
                        listener,
                        pipeline_factory(eng, m, 64),
                        fp,
                        NodeConfig::default(),
                        Some(1),
                    )
                    .unwrap();
                });
                let mut lane = RemoteLane::connect(&addr, fp, RemoteConfig::default()).unwrap();
                for t in tasks.clone() {
                    assert!(lane.push(t));
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish().unwrap();
                node.join().unwrap();
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // the loopback node again, but the gateway negotiates the v4 q15
    // payload and ships the tone workload — plus a one-shot
    // bytes-on-wire comparison against f32 framing via the
    // gateway_wire_frame_bytes_total counter. On smooth audio the
    // delta codec's second-order residuals fit one varint byte per
    // sample, so the ratio must clear 3.5x (a regression here means
    // the predictor or the varint packer broke).
    {
        let (eng, m) = (eng.clone(), m.clone());
        let tone = tone_workload();
        let fp = m.fingerprint();
        let session_bytes = |wf: WireFormat| -> u64 {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let (eng, m) = (eng.clone(), m.clone());
            let node = std::thread::spawn(move || {
                serve_node(
                    listener,
                    pipeline_factory(eng, m, 64),
                    fp,
                    NodeConfig::default(),
                    Some(1),
                )
                .unwrap();
            });
            let counter = registry().counter("gateway_wire_frame_bytes_total");
            let before = counter.get();
            let rcfg = RemoteConfig { wire_format: wf, ..RemoteConfig::default() };
            let mut lane = RemoteLane::connect(&addr, fp, rcfg).unwrap();
            for t in tone.clone() {
                assert!(lane.push(t));
            }
            lane.drain().unwrap();
            let (report, _) = lane.finish().unwrap();
            node.join().unwrap();
            assert_eq!(report.clips_classified, total_clips);
            counter.get() - before
        };
        let f32_bytes = session_bytes(WireFormat::F32);
        let q15_bytes = session_bytes(WireFormat::Q15);
        let ratio = f32_bytes as f64 / q15_bytes as f64;
        eprintln!(
            "wire bytes (tone workload): f32 {f32_bytes}, q15 {q15_bytes}, ratio {ratio:.2}x"
        );
        assert!(
            ratio >= 3.5,
            "q15 framing only saved {ratio:.2}x over f32 (need >= 3.5x): \
             f32 {f32_bytes} B vs q15 {q15_bytes} B"
        );

        b.run_with_throughput(
            "dispatch/remote_1node_q15",
            Some((total_clips as f64, "clips")),
            || {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap().to_string();
                let (eng, m) = (eng.clone(), m.clone());
                let node = std::thread::spawn(move || {
                    serve_node(
                        listener,
                        pipeline_factory(eng, m, 64),
                        fp,
                        NodeConfig::default(),
                        Some(1),
                    )
                    .unwrap();
                });
                let rcfg = RemoteConfig {
                    wire_format: WireFormat::Q15,
                    ..RemoteConfig::default()
                };
                let mut lane = RemoteLane::connect(&addr, fp, rcfg).unwrap();
                for t in tone.clone() {
                    assert!(lane.push(t));
                }
                lane.drain().unwrap();
                let (report, _) = lane.finish().unwrap();
                node.join().unwrap();
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // two loopback nodes behind a RemotePool: the fan-out tax on top of
    // remote_1node (second connection, hash routing, concurrent drain
    // barriers, merged reporting)
    {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        let fp = m.fingerprint();
        b.run_with_throughput(
            "dispatch/remote_2node_pool",
            Some((total_clips as f64, "clips")),
            || {
                let addrs: Vec<String> = (0..2)
                    .map(|_| {
                        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                        let addr = listener.local_addr().unwrap().to_string();
                        let (eng, m) = (eng.clone(), m.clone());
                        std::thread::spawn(move || {
                            serve_node(
                                listener,
                                pipeline_factory(eng, m, 64),
                                fp,
                                NodeConfig::default(),
                                Some(1),
                            )
                            .unwrap();
                        });
                        addr
                    })
                    .collect();
                let mut pool = RemotePool::connect(&addrs, fp, RemoteConfig::default()).unwrap();
                for t in tasks.clone() {
                    assert!(pool.push(t));
                }
                Lane::drain(&mut pool).unwrap();
                let (report, _) = Lane::finish(pool).unwrap();
                assert_eq!(report.clips_classified, total_clips);
                report.clips_classified
            },
        );
    }

    // sharded: 1 / 2 / 4 worker lanes over the identical workload
    for shards in [1usize, 2, 4] {
        let (eng, m, tasks) = (eng.clone(), m.clone(), tasks.clone());
        let name = format!("dispatch/sharded_{shards}lane");
        b.run_with_throughput(&name, Some((total_clips as f64, "clips")), || {
            let eng = eng.clone();
            let mut lane = ShardedPipeline::builder(shards, move |_| Ok(eng.clone()), m.clone())
                .queue_capacity(64)
                .build()
                .unwrap();
            for t in tasks.clone() {
                Lane::push(&mut lane, t);
            }
            Lane::drain(&mut lane).unwrap();
            let (report, _) = Lane::finish(lane).unwrap();
            assert_eq!(report.clips_classified, total_clips);
            report.clips_classified
        });
    }

    b.finish();
}
