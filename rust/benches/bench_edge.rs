//! Edge-ingest benches: the multiplierless gate's per-sample cost (the
//! number that must be negligible next to the MP bank for gating to pay
//! off), ring/session bookkeeping, the token bucket, and the pure-rust
//! CPU backend's frame step that the fleet classifies through.

use infilter::bench_util::Bench;
use infilter::coordinator::{ClassifyResult, FrameTask, PipelineBuilder};
use infilter::dsp::multirate::BandPlan;
use infilter::edge::ring::FrameRing;
use infilter::edge::session::{EdgeSession, SessionConfig, AMBIENT_LABEL};
use infilter::edge::uplink::TokenBucket;
use infilter::edge::vad::{EnergyGate, GateConfig};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::time::Instant;

fn main() {
    let mut b = Bench::new("bench_edge");
    let mut rng = Pcg32::new(2);

    // gate: quantised 2048-sample frame through the integer path
    let frame_f: Vec<f32> = (0..2048).map(|_| (rng.normal() * 0.02) as f32).collect();
    let mut gate = EnergyGate::new(GateConfig::default());
    let frame_q = gate.quantize(&frame_f);
    b.run_with_throughput("edge/gate_push_frame/2048", Some((2048.0, "samples")), || {
        gate.push_frame(&frame_q)
    });
    b.run_with_throughput("edge/gate_quantize/2048", Some((2048.0, "samples")), || {
        gate.quantize(&frame_f)
    });

    // ring: push + lookback snapshot
    let mut ring = FrameRing::new(4, 2048);
    b.run("edge/ring_push/2048", || ring.push(&frame_f));
    ring.push(&frame_f);
    b.run("edge/ring_last_n/2", || ring.last_n(2).len());

    // session: ambient frame end to end (gate + ring, no emission)
    let mut session = EdgeSession::new(SessionConfig::new(0, 2048, 8));
    let mut out = Vec::new();
    b.run_with_throughput("edge/session_ambient_frame/2048", Some((2048.0, "samples")), || {
        out.clear();
        session.push_frame(&frame_f, AMBIENT_LABEL, &mut out);
        out.len()
    });

    // uplink token bucket
    let mut bucket = TokenBucket::new(4096.0, 16_384.0);
    b.run("edge/token_bucket_tick_take", || {
        bucket.tick(0.128);
        bucket.try_take(32.0)
    });

    // the CPU backend's MP frame step (what a triggered frame costs)
    let plan = BandPlan::paper_default();
    let mut eng = CpuEngine::new(&plan, 1.0);
    let mut state = eng.zero_state();
    let loud: Vec<f32> = (0..2048).map(|_| (rng.normal() * 0.2) as f32).collect();
    b.run_with_throughput("edge/cpu_mp_frame/2048", Some((2048.0, "samples")), || {
        eng.frame_features(&mut state, &loud)
    });

    // one triggered clip end to end through an owned compute lane
    // (push → tick → clip-end inference), the unit of work the fleet
    // hands the coordinator per detection. The lane lives across
    // iterations (results streamed, not collected) so the measured
    // region excludes pipeline construction; clip_seq increments per
    // iteration to satisfy the in-order clip protocol.
    let mut plan_small = BandPlan::paper_default();
    plan_small.n_octaves = 3;
    let small = CpuEngine::with_clip(&plan_small, 1.0, 256, 4);
    let model = TrainedModel::synthetic(5, 10, small.n_filters(), 5.0, 5.0);
    let clip: Vec<f32> = (0..256 * 4).map(|_| (rng.normal() * 0.2) as f32).collect();
    let mut lane = PipelineBuilder::new(small, model)
        .queue_capacity(8)
        .sink(Box::new(|_: &ClassifyResult| {}))
        .collect_results(false)
        .build();
    let mut clip_seq = 0u64;
    b.run_with_throughput("edge/pipeline_clip/256x4", Some((1024.0, "samples")), || {
        for (f, frame) in clip.chunks(256).enumerate() {
            lane.push(FrameTask {
                stream: 0,
                clip_seq,
                frame_idx: f,
                data: frame.to_vec(),
                label: 0,
                t_gen: Instant::now(),
            });
        }
        clip_seq += 1;
        lane.drain().unwrap();
        lane.report().clips_classified
    });

    b.finish();
}
