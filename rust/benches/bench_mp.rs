//! L1 core-operator microbenches: the MP operator in every
//! implementation (rust exact sort, rust Newton, integer shift-Newton,
//! and the AOT `mp_op` HLO batch) — the unit costs behind every
//! table/figure.

use infilter::bench_util::Bench;
use infilter::fixed::mp_int;
use infilter::mp;
use infilter::mp::kernel;
use infilter::util::prng::Pcg32;

fn main() {
    let mut b = Bench::new("bench_mp");
    let mut rng = Pcg32::new(1);

    for n in [8usize, 32, 61, 128] {
        let xs = rng.normal_vec(n);
        b.run(&format!("mp/exact_sort/n{n}"), || mp::mp(&xs, 1.5));
        b.run(&format!("mp/newton_n_iters/n{n}"), || {
            mp::mp_newton(&xs, 1.5, n)
        });
        b.run(&format!("mp/newton_8_iters/n{n}"), || {
            mp::mp_newton(&xs, 1.5, 8)
        });
        let q: Vec<i64> = xs.iter().map(|&x| (x * 1024.0) as i64).collect();
        let iters = mp_int::default_iters(n, 10);
        b.run(&format!("mp/int_shift_newton/n{n}"), || {
            mp_int::mp_int(&q, 1536, iters)
        });
    }

    // the shared kernel's antisymmetric evaluator vs the exact sort over
    // the same virtual 2m row — the per-evaluation old-vs-new unit cost
    for m in [6usize, 16, 32] {
        let a = rng.normal_vec(m);
        b.run(&format!("mp/kernel_sym_newton/m{m}"), || {
            kernel::mp_sym(&a, 1.5, kernel::DEFAULT_NEWTON_ITERS)
        });
        let full: Vec<f32> = a.iter().copied().chain(a.iter().map(|&v| -v)).collect();
        b.run(&format!("mp/exact_sort_sym/m{m}"), || mp::mp(&full, 1.5));
    }

    // eq. 9 filter step in every implementation (2 MP evals over 2M)
    let hf = rng.normal_vec(16);
    let wf = rng.normal_vec(16);
    let mut row = vec![0.0f32; 16];
    b.run("mp/kernel_fir_step/taps16", || {
        kernel::mp_fir_step(&hf, wf[0], &wf[1..], 1.0, kernel::DEFAULT_NEWTON_ITERS, &mut row)
    });
    b.run("mp/exact_fir_eval/taps16", || {
        kernel::mp_fir_eval_exact(&hf, &wf, 1.0)
    });
    let h: Vec<i64> = rng.normal_vec(16).iter().map(|&x| (x * 256.0) as i64).collect();
    let w: Vec<i64> = rng.normal_vec(16).iter().map(|&x| (x * 256.0) as i64).collect();
    let mut scratch = vec![0i64; 32];
    b.run("mp/int_fir_step/taps16", || {
        mp_int::mp_fir_step(&h, &w, 256, 22, &mut scratch)
    });

    // HLO batched op (256 rows x 32) if artifacts exist
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut rt = infilter::runtime::Runtime::open(std::path::Path::new("artifacts")).unwrap();
        let x = rng.normal_vec(256 * 32);
        rt.call("mp_op", &[x.clone(), vec![1.0]]).unwrap(); // warm compile
        b.run_with_throughput("mp/hlo_mp_op/rows256_n32", Some((256.0, "rows")), || {
            rt.call("mp_op", &[x.clone(), vec![1.0]]).unwrap()
        });
    }
    b.finish();
}
