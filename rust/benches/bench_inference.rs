//! Inference-engine benches: the MP kernel machine head in float rust,
//! integer hardware model, and through the HLO artifacts (single +
//! batched eval) — the per-clip decision cost of Tables III/IV.

use infilter::bench_util::Bench;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::mp::machine::{decide, Params, Standardizer};
use infilter::runtime::engine::ModelEngine;
use infilter::util::prng::Pcg32;
use std::path::Path;

fn main() {
    let mut b = Bench::new("bench_inference");
    let mut rng = Pcg32::new(3);
    let p = 30;
    let mk_params = |heads: usize, rng: &mut Pcg32| Params {
        wp: (0..heads).map(|_| rng.normal_vec(p)).collect(),
        wm: (0..heads).map(|_| rng.normal_vec(p)).collect(),
        bp: rng.normal_vec(heads),
        bm: rng.normal_vec(heads),
    };
    let params10 = mk_params(10, &mut rng);
    let params2 = mk_params(2, &mut rng);
    let k = rng.normal_vec(p);

    b.run("infer/rust_float/c10", || decide(&params10, &k, 4.0));
    b.run("infer/rust_float/c2", || decide(&params2, &k, 4.0));

    // integer inference engine
    let std = Standardizer {
        mu: vec![0.0; p],
        sigma: vec![1.0; p],
    };
    let train_phi = vec![rng.uniform_vec(p, 0.0, 100.0); 8];
    let pipe = FixedPipeline::build(
        &infilter::dsp::multirate::BandPlan::paper_default(),
        1.0, 4.0, &params10, &std, &train_phi, FixedConfig::with_bits(8),
    );
    let kq: Vec<i64> = k.iter().map(|&x| (x * 16.0) as i64).collect();
    b.run("infer/int8_hw_model/c10", || pipe.infer(&kq));

    if Path::new("artifacts/manifest.json").exists() {
        let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0).unwrap();
        let phi = rng.uniform_vec(p, 0.0, 100.0);
        let st = Standardizer {
            mu: rng.uniform_vec(p, 20.0, 60.0),
            sigma: rng.uniform_vec(p, 5.0, 20.0),
        };
        eng.inference(&params10, &st, &phi, 4.0).unwrap();
        b.run("infer/hlo_single/c10", || {
            eng.inference(&params10, &st, &phi, 4.0).unwrap()
        });
        let rows: Vec<Vec<f32>> = (0..64).map(|_| rng.normal_vec(p)).collect();
        eng.eval_margins(&params10, &rows, 4.0).unwrap();
        b.run_with_throughput("infer/hlo_eval_batch64/c10", Some((64.0, "clips")), || {
            eng.eval_margins(&params10, &rows, 4.0).unwrap()
        });
        // train step (the driver's unit cost)
        let mut pm = params10.clone();
        let kb = rng.normal_vec(64 * p);
        let yb = rng.uniform_vec(64 * 10, 0.0, 1.0);
        eng.train_step(&mut pm, &kb, &yb, 0.1, 4.0).unwrap();
        b.run("train/hlo_train_step/c10_b64", || {
            eng.train_step(&mut pm, &kb, &yb, 0.1, 4.0).unwrap()
        });
    }
    b.finish();
}
