//! Fixed-point hardware-model benches: the Fig. 8 / Table I datapath
//! costs — integer MP filter-bank accumulate per clip, quantisation,
//! CSD standardisation.

use infilter::bench_util::Bench;
use infilter::dsp::multirate::BandPlan;
use infilter::fixed::q::{CsdScale, QFormat};
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::mp::machine::{Params, Standardizer};
use infilter::util::prng::Pcg32;

fn main() {
    let mut b = Bench::new("bench_fixed");
    let mut rng = Pcg32::new(4);
    let plan = BandPlan::paper_default();
    let clip: Vec<f32> = rng.normal_vec(16384).iter().map(|x| 0.25 * x).collect();
    let train_phi = vec![rng.uniform_vec(30, 10.0, 100.0); 8];
    let std = Standardizer {
        mu: rng.uniform_vec(30, 20.0, 60.0),
        sigma: rng.uniform_vec(30, 5.0, 20.0),
    };
    for bits in [8u32, 12] {
        let pipe = FixedPipeline::build(
            &plan, 1.0, 4.0,
            &Params::zeros(2, 30), &std, &train_phi,
            FixedConfig::with_bits(bits),
        );
        b.run_with_throughput(
            &format!("fixed/accumulate_clip16384/w{bits}"),
            Some((1.024, "audio_s")),
            || pipe.accumulate(&clip),
        );
        let acc = pipe.accumulate(&clip);
        b.run(&format!("fixed/standardize/w{bits}"), || {
            pipe.standardize(&acc)
        });
    }
    let q = QFormat::new(8, 6);
    b.run_with_throughput("fixed/quantize_16k_samples", Some((16384.0, "samples")), || {
        q.quantize_vec(&clip)
    });
    let csd = CsdScale::approximate(0.731, 3);
    b.run("fixed/csd_apply", || csd.apply(12345));
    b.finish();
}
