//! End-to-end benches: full-clip classification (features + inference
//! through the HLO artifacts) and the streaming coordinator's serving
//! throughput — the headline realtime-factor numbers in EXPERIMENTS.md.

use infilter::bench_util::Bench;
use infilter::coordinator::server::{serve, ServeConfig};
use infilter::datasets::esc10;
use infilter::mp::machine::{Params, Standardizer};
use infilter::runtime::engine::ModelEngine;
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::path::Path;

fn main() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_e2e: artifacts not built, skipping");
        return;
    }
    let mut b = Bench::new("bench_e2e");
    let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0).unwrap();
    let clip_len = eng.frame_len() * eng.clip_frames();
    let mut rng = Pcg32::new(6);
    let model = TrainedModel {
        classes: (0..10).map(|c| format!("c{c}")).collect(),
        params: Params {
            wp: (0..10).map(|_| rng.normal_vec(30)).collect(),
            wm: (0..10).map(|_| rng.normal_vec(30)).collect(),
            bp: vec![0.0; 10],
            bm: vec![0.0; 10],
        },
        std: Standardizer {
            mu: vec![50.0; 30],
            sigma: vec![20.0; 30],
        },
        gamma_f: 1.0,
        gamma_1: 4.0,
    };

    let clip = esc10::synth_clip(7, 3, 0);
    let samples = &clip.samples[..clip_len];
    // full single-clip path: features (8 frames) + inference
    eng.clip_features(samples).unwrap();
    b.run_with_throughput("e2e/classify_one_clip", Some((1.024, "audio_s")), || {
        let phi = eng.clip_features(samples).unwrap();
        eng.inference(&model.params, &model.std, &phi, 4.0).unwrap()
    });

    // serving throughput, 8 streams x 1 clip, max rate (one number per
    // bench sample is a full serve run — keep the workload small)
    std::env::set_var("INFILTER_BENCH_QUICK", "1");
    let cfg = ServeConfig {
        n_streams: 8,
        clips_per_stream: 1,
        seed: 1,
        ..Default::default()
    };
    b.run_with_throughput("e2e/serve_8streams_1clip", Some((8.0 * 1.024, "audio_s")), || {
        serve(&mut eng, &model, &cfg).unwrap()
    });
    b.finish();
}
