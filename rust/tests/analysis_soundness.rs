//! Soundness harness for the static bit-width prover (DESIGN.md §11).
//!
//! The prover (`infilter::analysis`) claims a worst-case interval for
//! every datapath register; the checked-arithmetic debug mode of the
//! fixed-point pipeline (`classify_traced` + `RangeTrace`) records what
//! values those registers actually take on concrete clips. Soundness
//! means the static claim dominates every observation:
//!
//!   * every observed stage key has a matching analyzed stage,
//!   * every observed (min, max) lies inside the proven interval,
//!   * saturation events only ever occur at stages the prover marks as
//!     saturating (clipping) registers — a clip at a wrap-semantics
//!     stage would mean the proof missed an overflow path.
//!
//! Exercised on adversarial fixed clips (full-scale squares, impulse
//! trains, chirps) and on property-tested random banks across widths.

use infilter::analysis::{analyze, Provision};
use infilter::dsp::chirp;
use infilter::dsp::multirate::BandPlan;
use infilter::fixed::pipeline::{FixedConfig, FixedPipeline};
use infilter::fixed::RangeTrace;
use infilter::mp::filter::MpMultirateBank;
use infilter::mp::machine::{Params, Standardizer};
use infilter::util::prng::Pcg32;
use infilter::util::proptest::check;

/// A small calibrated pipeline over the real paper filter bank
/// (truncated to `n_octaves` so debug-mode runs stay fast), with a
/// random 2-head model — the same construction the pipeline unit tests
/// use, parameterised by seed.
fn build_pipe(seed: u64, bits: u32, n_octaves: usize) -> (BandPlan, FixedPipeline) {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = n_octaves;
    let mut rng = Pcg32::new(seed);
    let feats = plan.n_filters();
    let params = Params {
        wp: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        wm: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        bp: vec![0.1, -0.2],
        bm: vec![-0.1, 0.2],
    };
    let mut bank = MpMultirateBank::new(&plan, 1.0);
    let phis: Vec<Vec<f32>> = (0..6u64)
        .map(|i| {
            bank.reset();
            let clip: Vec<f32> = Pcg32::new(seed.wrapping_add(100 + i))
                .normal_vec(2048)
                .iter()
                .map(|x| 0.3 * x)
                .collect();
            bank.features(&clip)
        })
        .collect();
    let std = Standardizer::fit(&phis);
    let pipe = FixedPipeline::build(
        &plan,
        1.0,
        4.0,
        &params,
        &std,
        &phis,
        FixedConfig::with_bits(bits),
    );
    (plan, pipe)
}

/// The core soundness check: every observation in `tr` must be
/// dominated by the static analysis of the same pipeline.
fn assert_trace_dominated(pipe: &FixedPipeline, clip_len: usize, tr: &RangeTrace) {
    let prov = Provision::for_pipeline(pipe, 24);
    let report = analyze(pipe, clip_len, &prov);
    assert!(!tr.ranges.is_empty(), "trace observed nothing");
    for (key, &(lo, hi)) in &tr.ranges {
        let stage = report
            .stage(key)
            .unwrap_or_else(|| panic!("stage '{key}' observed but never analyzed"));
        assert!(
            stage.interval.contains(lo) && stage.interval.contains(hi),
            "observed [{lo}, {hi}] at '{key}' escapes proven [{}, {}]",
            stage.interval.lo,
            stage.interval.hi
        );
    }
    for (key, &clips) in &tr.sat_counts {
        if clips == 0 {
            continue;
        }
        let stage = report
            .stage(key)
            .unwrap_or_else(|| panic!("saturations at unanalyzed stage '{key}'"));
        assert!(
            stage.saturating,
            "{clips} clip(s) at '{key}', which the prover models as a \
             wrap-semantics register — the proof missed an overflow path"
        );
    }
}

#[test]
fn adversarial_clips_stay_inside_proven_bounds() {
    let (plan, pipe) = build_pipe(7, 10, 3);
    let n = 4096usize;
    // full-scale square wave (worst-case register excitation), impulse
    // train, tone, chirp, and an out-of-range clip the input quantizer
    // must clamp
    let square: Vec<f32> = (0..n).map(|i| if (i / 16) % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let impulses: Vec<f32> = (0..n).map(|i| if i % 64 == 0 { 1.0 } else { 0.0 }).collect();
    let tone = chirp::tone(2500.0, n, plan.sample_rate, 0.95);
    let sweep = chirp::linear_chirp(100.0, 7500.0, n, plan.sample_rate);
    let hot: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.8 } else { -1.8 }).collect();
    let mut tr = RangeTrace::new();
    for clip in [&square, &impulses, &tone, &sweep, &hot] {
        let p = pipe.classify_traced(clip, &mut tr);
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|x| x.is_finite()));
    }
    // the full path must have been observed, bank through inference
    assert!(tr.range("input").is_some());
    assert!(tr.range("bp[0].resid").is_some());
    assert!(tr.range("acc[0]").is_some());
    assert!(tr.range("inf.margin").is_some());
    assert_trace_dominated(&pipe, n, &tr);
}

#[test]
fn paper_width_toy_bank_is_certified_and_16_bit_accumulator_is_not() {
    // mirrors the CI gate: W = 10 with the paper's 24-bit accumulator
    // certifies on a real (truncated) bank, and the injected regression
    // --acc-bits 16 is caught as an overflow at the kernel accumulator
    let (_, pipe) = build_pipe(11, 10, 3);
    let ok = analyze(&pipe, 16_000, &Provision::for_pipeline(&pipe, 24));
    assert!(ok.certified(), "{}", ok.render());
    let bad = analyze(&pipe, 16_000, &Provision::for_pipeline(&pipe, 16));
    assert!(!bad.certified(), "{}", bad.render());
    assert!(bad.overflows().iter().all(|s| s.name.starts_with("acc[")));
}

#[test]
fn random_banks_and_widths_stay_dominated() {
    // property test: random model seeds, datapath widths and clip
    // content — the static bound must dominate every observation
    check("analysis-soundness", 6, |g| {
        let bits = g.usize(6, 14) as u32;
        let n_oct = g.usize(2, 3);
        let (_, pipe) = build_pipe(g.seed, bits, n_oct);
        let clip = g.signal(2048, 0.9);
        let mut tr = RangeTrace::new();
        pipe.classify_traced(&clip, &mut tr);
        assert_trace_dominated(&pipe, 2048, &tr);
    });
}
