//! Deterministic chaos acceptance for the cross-process serving stack,
//! tier-1 safe (loopback TCP, port 0, bounded windows, no external
//! network): every wire fault the [`ChaosProxy`] can inject, every
//! labelled node-side crash point, and the wedged-session idle reaper —
//! each round checked against the shared [`Invariants`] accounting
//! contract plus bit-parity-or-accounted-loss of everything delivered.
//! Every failure message carries the reproducing seed; replay a round
//! outside the suite with `infilter chaos-soak --seed <seed>`
//! (docs/OPERATIONS.md §Chaos testing).
//!
//! The node-side fault table is process-global, and every scenario here
//! spawns node sessions inside this test binary, so the whole suite
//! runs one test at a time behind [`serial`] — an armed fault can never
//! leak into a neighbouring scenario.
//!
//! [`ChaosProxy`]: infilter::net::ChaosProxy
//! [`Invariants`]: infilter::net::Invariants

use infilter::coordinator::dispatch::Lane;
use infilter::coordinator::FrameTask;
use infilter::dsp::multirate::BandPlan;
use infilter::net::chaos::{
    arm_node_fault, disarm_node_faults, run_scenario, ScenarioConfig,
};
use infilter::net::node::pipeline_factory;
use infilter::net::{
    serve_node_until, FaultKind, Invariants, NodeConfig, NodeFaultAction, NodeFaultPoint,
    NodeShutdown, RemoteConfig, RemoteLane, WireFormat,
};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::telemetry::registry;
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
}

fn engine() -> CpuEngine {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    CpuEngine::with_clip(&plan, 1.0, 64, 2)
}

fn model() -> TrainedModel {
    TrainedModel::synthetic(11, 4, engine().n_filters(), 0.0, 1.0)
}

fn clip_frames(stream: u64, clip: u64) -> Vec<FrameTask> {
    let mut rng = Pcg32::substream(113 ^ clip.wrapping_mul(29), stream);
    (0..2usize)
        .map(|f| FrameTask {
            stream,
            clip_seq: clip,
            frame_idx: f,
            data: (0..64).map(|_| (rng.normal() * 0.1) as f32).collect(),
            label: (stream % 4) as usize,
            t_gen: Instant::now(),
        })
        .collect()
}

fn spawn_node(
    m: TrainedModel,
    cfg: NodeConfig,
) -> (String, NodeShutdown, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let fp = m.fingerprint();
    let stop = NodeShutdown::new();
    let handle = std::thread::spawn({
        let stop = stop.clone();
        move || {
            serve_node_until(listener, pipeline_factory(engine(), m, 64), fp, cfg, None, stop)
                .expect("node serving");
        }
    });
    (addr, stop, handle)
}

/// Keep dialling until the node admits a session (a reaped or released
/// slot re-admits within milliseconds; the deadline is pure slack).
fn connect_eventually(addr: &str, m: &TrainedModel) -> RemoteLane {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match RemoteLane::connect(addr, m.fingerprint(), RemoteConfig::default()) {
            Ok(lane) => return lane,
            Err(e) if Instant::now() >= deadline => {
                panic!("no session admitted within the deadline: {e:#}")
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Every scenario round runs with the [`ConformanceMonitor`] armed
/// (`ScenarioConfig::quick` sets `monitor: true`): whatever a fault
/// does to the session, the gateway's observable trace must stay one
/// the protocol spec machines would produce. A divergence is an
/// implementation/spec drift, never a tolerated chaos outcome, so it
/// fails the round with the reproducing seed.
///
/// [`ConformanceMonitor`]: infilter::net::ConformanceMonitor
fn assert_conformant(seed: u64, out: &infilter::net::chaos::ScenarioOutcome) {
    assert!(
        out.spec_divergences.is_empty(),
        "[chaos seed {seed:#x}] conformance monitor diverged from the protocol \
         spec:\n  {}\nREPRODUCE: infilter chaos-soak --seed {seed:#x}",
        out.spec_divergences.join("\n  ")
    );
}

/// One seeded round under a lethal wire fault: the proxy must actually
/// fire, and whatever the timing dealt, the accounting contract and the
/// bit-parity of everything delivered must hold.
fn lethal_round(kind: FaultKind, seed: u64) {
    let cfg = ScenarioConfig::quick(seed, vec![kind]);
    let out = run_scenario(&cfg)
        .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] scenario failed: {e:#}"));
    assert!(
        out.faults_injected >= 1,
        "[chaos seed {seed:#x}] the proxy never fired {kind:?}"
    );
    assert_conformant(seed, &out);
    let inv = Invariants::new(out.clips_pushed).seeded(seed);
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

/// One seeded round under a shaping (non-lethal) fault: traffic is
/// delayed or throttled but nothing may be lost — full bit parity.
fn shaped_round(kind: FaultKind, seed: u64) {
    let cfg = ScenarioConfig::quick(seed, vec![kind]);
    let out = run_scenario(&cfg)
        .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] scenario failed: {e:#}"));
    assert!(
        out.faults_injected >= 1,
        "[chaos seed {seed:#x}] the proxy never shaped the connection with {kind:?}"
    );
    assert_conformant(seed, &out);
    let inv = Invariants::new(out.clips_pushed).seeded(seed).lossless();
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

// ---------------------------------------------------------------------
// wire faults, one deterministic round per kind
// ---------------------------------------------------------------------

#[test]
fn delay_shaping_is_lossless_and_bit_exact() {
    let _g = serial();
    shaped_round(FaultKind::Delay, 0xDE1A);
}

#[test]
fn throttle_shaping_is_lossless_and_bit_exact() {
    let _g = serial();
    shaped_round(FaultKind::Throttle, 0x7B07);
}

#[test]
fn dropped_connection_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::DropConn, 0xD60B);
}

#[test]
fn half_close_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::HalfClose, 0x4A1F);
}

#[test]
fn rst_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::Rst, 0x2572);
}

#[test]
fn stall_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::Stall, 0x57A1);
}

#[test]
fn truncated_frame_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::TruncateFrame, 0x7B0C);
}

#[test]
fn corrupt_length_prefix_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::CorruptLen, 0xC02F);
}

#[test]
fn corrupt_payload_round_keeps_accounting_exact() {
    let _g = serial();
    lethal_round(FaultKind::CorruptPayload, 0xC0FB);
}

#[test]
fn pool_round_with_dead_lanes_sums_per_lane_accounting() {
    let _g = serial();
    let seed = 0x9001;
    let cfg = ScenarioConfig {
        streams: 6,
        nodes: 2,
        ..ScenarioConfig::quick(seed, vec![FaultKind::DropConn])
    };
    let out = run_scenario(&cfg)
        .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] scenario failed: {e:#}"));
    assert!(
        out.faults_injected >= 1,
        "[chaos seed {seed:#x}] no proxy fired"
    );
    assert_conformant(seed, &out);
    let inv = Invariants::new(out.clips_pushed).seeded(seed).pool(2);
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

/// The chaos stall plus the idle reaper together: while the proxy
/// absorbs traffic the node session goes silent, the reaper frees its
/// slot mid-run, and the gateway's failover still accounts every clip.
#[test]
fn stall_round_with_idle_reaping_stays_consistent() {
    let _g = serial();
    let seed = 0x1D1E;
    let cfg = ScenarioConfig {
        idle_timeout: Some(Duration::from_millis(100)),
        ..ScenarioConfig::quick(seed, vec![FaultKind::Stall])
    };
    let out = run_scenario(&cfg)
        .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] scenario failed: {e:#}"));
    assert_conformant(seed, &out);
    let inv = Invariants::new(out.clips_pushed).seeded(seed);
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

// ---------------------------------------------------------------------
// wire protocol v4: quantized (q15) frame payloads under chaos
// ---------------------------------------------------------------------

/// One seeded round with the v4 `FrameQ` payload negotiated in the
/// handshake. `ScenarioConfig` pre-snaps the workload to the q15 grid,
/// so the codec is the identity on these samples and the bit-parity
/// half of [`Invariants`] carries over unchanged — any disagreement is
/// a codec or framing bug, not quantization noise.
fn q15_round(kind: FaultKind, seed: u64, lossless: bool) {
    let cfg = ScenarioConfig {
        wire_format: WireFormat::Q15,
        ..ScenarioConfig::quick(seed, vec![kind])
    };
    let out = run_scenario(&cfg)
        .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] q15 scenario failed: {e:#}"));
    assert!(
        out.faults_injected >= 1,
        "[chaos seed {seed:#x}] the proxy never fired {kind:?}"
    );
    assert_conformant(seed, &out);
    let mut inv = Invariants::new(out.clips_pushed).seeded(seed);
    if lossless {
        inv = inv.lossless();
    }
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

#[test]
fn q15_delay_shaping_is_lossless_and_bit_exact() {
    let _g = serial();
    q15_round(FaultKind::Delay, 0x0415A, true);
}

#[test]
fn q15_dropped_connection_round_keeps_accounting_exact() {
    let _g = serial();
    q15_round(FaultKind::DropConn, 0x0415B, false);
}

#[test]
fn q15_truncated_frame_round_keeps_accounting_exact() {
    let _g = serial();
    // truncation now lands mid-FrameQ: the varint decoder must reject,
    // never panic, and the session death must account every clip
    q15_round(FaultKind::TruncateFrame, 0x0415C, false);
}

#[test]
fn q15_corrupt_payload_round_keeps_accounting_exact() {
    let _g = serial();
    q15_round(FaultKind::CorruptPayload, 0x0415D, false);
}

// ---------------------------------------------------------------------
// node-side crash/stall points
// ---------------------------------------------------------------------

/// One seeded round with a crash armed at a labelled node fault point
/// and a clean wire: the gateway must observe the death, fail over, and
/// keep the accounting contract.
fn node_crash_round(point: NodeFaultPoint, seed: u64) {
    disarm_node_faults();
    arm_node_fault(point, NodeFaultAction::CrashSession);
    let cfg = ScenarioConfig::quick(seed, vec![]);
    let out = run_scenario(&cfg).unwrap_or_else(|e| {
        disarm_node_faults();
        panic!("[chaos seed {seed:#x}] scenario failed: {e:#}")
    });
    disarm_node_faults();
    assert_conformant(seed, &out);
    assert!(
        out.report.reconnects >= 1,
        "[chaos seed {seed:#x}] the crash at {point:?} never forced a failover"
    );
    let inv = Invariants::new(out.clips_pushed).seeded(seed);
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

#[test]
fn node_crash_mid_compute_is_survived() {
    let _g = serial();
    node_crash_round(NodeFaultPoint::MidCompute, 0x3C01);
}

#[test]
fn node_crash_before_drain_ack_is_survived() {
    let _g = serial();
    node_crash_round(NodeFaultPoint::PreDrainAck, 0x3C02);
}

#[test]
fn node_crash_before_flush_ack_is_survived() {
    let _g = serial();
    node_crash_round(NodeFaultPoint::PreFlushAck, 0x3C03);
}

#[test]
fn node_crash_at_admission_releases_the_slot() {
    let _g = serial();
    disarm_node_faults();
    let m = model();
    let (addr, stop, node) = spawn_node(
        m.clone(),
        NodeConfig {
            credits: 16,
            max_sessions: 1,
            ..NodeConfig::default()
        },
    );
    arm_node_fault(NodeFaultPoint::Admission, NodeFaultAction::CrashSession);
    let denied = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default());
    assert!(
        denied.is_err(),
        "the armed admission crash kills the first session before its Welcome"
    );
    // the crashed session held the only slot; a leak would make every
    // further handshake Busy forever
    let mut lane = connect_eventually(&addr, &m);
    for t in clip_frames(3, 0) {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    let (report, results) = lane.finish().unwrap();
    stop.shutdown();
    node.join().unwrap();
    disarm_node_faults();
    Invariants::new(1).lossless().exact().assert_ok(&report);
    assert_eq!(results.len(), 1);
}

#[test]
fn node_stall_before_drain_ack_only_delays() {
    let _g = serial();
    let seed = 0x57A11;
    disarm_node_faults();
    arm_node_fault(
        NodeFaultPoint::PreDrainAck,
        NodeFaultAction::Stall(Duration::from_millis(150)),
    );
    let cfg = ScenarioConfig::quick(seed, vec![]);
    let out = run_scenario(&cfg).unwrap_or_else(|e| {
        disarm_node_faults();
        panic!("[chaos seed {seed:#x}] scenario failed: {e:#}")
    });
    disarm_node_faults();
    assert_conformant(seed, &out);
    // the stall is far below the gateway io_timeout: a hiccup, not a
    // death — the run must stay lossless and bit-exact
    let inv = Invariants::new(out.clips_pushed).seeded(seed).lossless().exact();
    inv.assert_ok(&out.report);
    inv.assert_results(&out.report, &out.results, &out.reference);
}

// ---------------------------------------------------------------------
// the wedged-session idle reaper
// ---------------------------------------------------------------------

#[test]
fn wedged_session_holds_the_slot_forever_without_idle_timeout() {
    let _g = serial();
    let m = model();
    let (addr, stop, node) = spawn_node(
        m.clone(),
        NodeConfig {
            credits: 16,
            max_sessions: 1,
            ..NodeConfig::default()
        },
    );
    // a wedged gateway: handshaken, then silent but never closing
    let wedged = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    let window = Instant::now() + Duration::from_millis(300);
    while Instant::now() < window {
        assert!(
            RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).is_err(),
            "without an idle timeout the wedged session must hold the only slot \
             for the whole soak window"
        );
        std::thread::sleep(Duration::from_millis(40));
    }
    // a *closed* session releases the slot promptly — the leak is the
    // wedge, not the teardown
    drop(wedged);
    let mut lane = connect_eventually(&addr, &m);
    for t in clip_frames(1, 0) {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    let (report, _) = lane.finish().unwrap();
    stop.shutdown();
    node.join().unwrap();
    Invariants::new(1).lossless().exact().assert_ok(&report);
}

#[test]
fn idle_timeout_reaps_the_wedged_session_and_readmits() {
    let _g = serial();
    let m = model();
    let reaps_before = registry().counter("node_idle_reaps_total").get();
    let (addr, stop, node) = spawn_node(
        m.clone(),
        NodeConfig {
            credits: 16,
            max_sessions: 1,
            session_idle_timeout: Some(Duration::from_millis(50)),
            ..NodeConfig::default()
        },
    );
    let wedged = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    // the node reaps the silent session after ~50ms; the freed slot
    // must admit a fresh gateway that then runs a full clean session
    let mut lane = connect_eventually(&addr, &m);
    for t in clip_frames(2, 0) {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    let (report, results) = lane.finish().unwrap();
    drop(wedged);
    stop.shutdown();
    node.join().unwrap();
    Invariants::new(1).lossless().exact().assert_ok(&report);
    assert_eq!(results.len(), 1);
    assert!(
        registry().counter("node_idle_reaps_total").get() > reaps_before,
        "the reap is counted in node_idle_reaps_total"
    );
}

// ---------------------------------------------------------------------
// mini soak: mixed seeded schedules, the CLI's loop in miniature
// ---------------------------------------------------------------------

#[test]
fn mini_soak_across_seeds_and_mixed_schedules() {
    let _g = serial();
    for seed in [0x51u64, 0x52, 0x53] {
        let mut rng = Pcg32::new(seed);
        let n = 1 + rng.below(2) as usize;
        let schedule: Vec<FaultKind> = (0..n)
            .map(|_| FaultKind::ALL[rng.below(FaultKind::ALL.len() as u32) as usize])
            .collect();
        let lethal = schedule.iter().any(|k| k.lethal());
        let cfg = ScenarioConfig {
            faults: schedule,
            ..ScenarioConfig::quick(seed, vec![])
        };
        let out = run_scenario(&cfg)
            .unwrap_or_else(|e| panic!("[chaos seed {seed:#x}] scenario failed: {e:#}"));
        assert_conformant(seed, &out);
        let mut inv = Invariants::new(out.clips_pushed).seeded(seed);
        if !lethal {
            inv = inv.lossless();
        }
        inv.assert_ok(&out.report);
        inv.assert_results(&out.report, &out.results, &out.reference);
    }
}
