//! Cross-process serving, tier-1 safe: everything runs over loopback
//! TCP on ephemeral ports (bind 127.0.0.1:0), no external network, no
//! artifacts. The core acceptance test is remote-vs-local parity — the
//! same synthetic clip set classified through an in-process `Pipeline`,
//! a `ShardedPipeline`, and a `RemoteLane` + in-process `infilter-node`
//! must produce bit-identical `ClassifyResult`s on the CPU backend.

use infilter::coordinator::dispatch::{Lane, PipelineBuilder};
use infilter::coordinator::shard::ShardedPipeline;
use infilter::coordinator::{ClassifyResult, FrameTask};
use infilter::dsp::multirate::BandPlan;
use infilter::net::node::pipeline_factory;
use infilter::net::{serve_node, Invariants, NodeConfig, RemoteConfig, RemoteLane, RemotePool};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::time::Instant;

fn engine() -> CpuEngine {
    // tiny geometry keeps the whole matrix fast in debug builds
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    CpuEngine::with_clip(&plan, 1.0, 64, 2)
}

fn model() -> TrainedModel {
    TrainedModel::synthetic(11, 4, engine().n_filters(), 0.0, 1.0)
}

/// Deterministic multi-stream workload, identical per invocation.
fn workload(n_streams: u64, clips: u64) -> Vec<FrameTask> {
    let mut out = Vec::new();
    for s in 0..n_streams {
        let mut rng = Pcg32::substream(41, s);
        for clip in 0..clips {
            for f in 0..2usize {
                out.push(FrameTask {
                    stream: s,
                    clip_seq: clip,
                    frame_idx: f,
                    data: (0..64).map(|_| (rng.normal() * 0.1) as f32).collect(),
                    label: (s % 4) as usize,
                    t_gen: Instant::now(),
                });
            }
        }
    }
    out
}

/// Spawn an in-process node serving `conns` sessions over a single-lane
/// pipeline; returns (address, join handle).
fn spawn_node(
    m: TrainedModel,
    conns: usize,
    credits: u32,
) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let fp = m.fingerprint();
    let handle = std::thread::spawn(move || {
        serve_node(
            listener,
            pipeline_factory(engine(), m, 64),
            fp,
            NodeConfig { credits, ..NodeConfig::default() },
            Some(conns),
        )
        .expect("node serving");
    });
    (addr, handle)
}

fn sorted(mut rs: Vec<ClassifyResult>) -> Vec<ClassifyResult> {
    rs.sort_by_key(|r| (r.stream, r.clip_seq));
    rs
}

#[test]
fn remote_matches_local_and_sharded_bit_exactly() {
    let m = model();

    // in-process single lane
    let mut local = PipelineBuilder::new(engine(), m.clone())
        .queue_capacity(64)
        .build();
    for t in workload(6, 2) {
        assert!(Lane::push(&mut local, t));
    }
    Lane::drain(&mut local).unwrap();
    let (local_report, local_results) = Lane::finish(local).unwrap();
    let local_results = sorted(local_results);
    assert_eq!(local_results.len(), 12);

    // in-process sharded (3 lanes)
    let mut sharded = ShardedPipeline::builder(3, |_| Ok(engine()), m.clone())
        .queue_capacity(64)
        .build()
        .unwrap();
    for t in workload(6, 2) {
        assert!(Lane::push(&mut sharded, t));
    }
    Lane::drain(&mut sharded).unwrap();
    let (_, sharded_results) = Lane::finish(sharded).unwrap();
    let sharded_results = sorted(sharded_results);

    // cross-process: RemoteLane -> loopback node
    let (addr, node) = spawn_node(m.clone(), 1, 32);
    let mut remote = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    assert_eq!(remote.frame_len(), 64);
    assert_eq!(remote.clip_frames(), 2);
    for t in workload(6, 2) {
        assert!(remote.push(t));
    }
    remote.drain().unwrap();
    let (remote_report, remote_results) = remote.finish().unwrap();
    node.join().unwrap();
    let remote_results = sorted(remote_results);

    // identical clip sets, bit-identical classifications
    assert_eq!(local_results.len(), sharded_results.len());
    assert_eq!(local_results.len(), remote_results.len());
    for ((a, b), c) in local_results
        .iter()
        .zip(&sharded_results)
        .zip(&remote_results)
    {
        assert_eq!((a.stream, a.clip_seq), (b.stream, b.clip_seq));
        assert_eq!((a.stream, a.clip_seq), (c.stream, c.clip_seq));
        assert_eq!(a.predicted, b.predicted);
        assert_eq!(a.predicted, c.predicted, "stream {} clip {}", a.stream, a.clip_seq);
        assert_eq!(a.p, b.p);
        assert_eq!(
            a.p, c.p,
            "remote scores must be bit-equal (stream {} clip {})",
            a.stream, a.clip_seq
        );
        assert_eq!(a.label, c.label);
    }
    // the node's report matches the local lane's counters and the
    // shared accounting contract (tests/net_chaos.rs runs the same
    // checker under injected faults)
    Invariants::new(12).lossless().exact().assert_ok(&remote_report);
    assert_eq!(remote_report.clips_classified, local_report.clips_classified);
    assert_eq!(
        remote_report.batch.frames_processed,
        local_report.batch.frames_processed
    );
}

#[test]
fn gateway_drain_is_a_wire_barrier() {
    // drain() must return only after the node has acked empty — at
    // which point every result is already on the gateway, with no
    // sleeps or polling needed
    let m = model();
    let (addr, node) = spawn_node(m.clone(), 1, 4);
    let mut remote = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    for round in 0..3u64 {
        for t in workload(4, 1) {
            let t = FrameTask {
                clip_seq: round,
                ..t
            };
            assert!(remote.push(t));
        }
        remote.drain().unwrap();
        assert_eq!(
            remote.clips_classified(),
            4 * (round + 1),
            "all of round {round}'s results must precede the drain ack"
        );
    }
    let (report, results) = remote.finish().unwrap();
    node.join().unwrap();
    Invariants::new(12).lossless().exact().assert_ok(&report);
    assert_eq!(results.len(), 12);
}

#[test]
fn pool_fans_out_across_nodes_and_merges_reports() {
    let m = model();
    let (addr_a, node_a) = spawn_node(m.clone(), 1, 32);
    let (addr_b, node_b) = spawn_node(m.clone(), 1, 32);
    let mut pool = RemotePool::connect(
        &[addr_a, addr_b],
        m.fingerprint(),
        RemoteConfig::default(),
    )
    .unwrap();
    assert_eq!(pool.nodes(), 2);
    // streams must spread over both nodes (fib hash, see shard tests)
    let hits: Vec<usize> = (0..8u64).map(|s| pool.route(s)).collect();
    assert!(hits.contains(&0) && hits.contains(&1));
    for t in workload(8, 1) {
        assert!(pool.push(t));
    }
    Lane::drain(&mut pool).unwrap();
    assert_eq!(pool.clips_classified(), 8);
    let (report, results) = Lane::finish(pool).unwrap();
    node_a.join().unwrap();
    node_b.join().unwrap();
    // lossless + exact + per-lane rows summing to the pool totals, via
    // the shared accounting checker
    Invariants::new(8).lossless().exact().pool(2).assert_ok(&report);
    assert_eq!(results.len(), 8);

    // and the pooled results equal a local run, bit for bit
    let mut local = PipelineBuilder::new(engine(), m).queue_capacity(64).build();
    for t in workload(8, 1) {
        Lane::push(&mut local, t);
    }
    Lane::drain(&mut local).unwrap();
    let (_, local_results) = Lane::finish(local).unwrap();
    let (pooled, local_sorted) = (sorted(results), sorted(local_results));
    for (a, b) in pooled.iter().zip(&local_sorted) {
        assert_eq!((a.stream, a.clip_seq), (b.stream, b.clip_seq));
        assert_eq!(a.p, b.p);
    }
}
