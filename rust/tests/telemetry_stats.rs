//! Live-telemetry acceptance: a loopback gateway→node serve with the
//! `--stats-listen` endpoint scraped mid-run over real HTTP, asserting
//! the required metric families are present and that their values
//! advance with the workload; plus the JSONL snapshot schema the CI
//! smoke step depends on.
//!
//! Gateway and node run in one process here, so both layers record
//! into the same global registry and a single scrape sees the full
//! `node_*` + `gateway_*` + `pipeline_*` picture. Assertions are
//! delta-based (scrape before vs. after) because the registry is
//! process-global and other tests in this binary may record too.

use infilter::coordinator::dispatch::Lane;
use infilter::coordinator::FrameTask;
use infilter::dsp::multirate::BandPlan;
use infilter::net::node::pipeline_factory;
use infilter::net::{serve_node, NodeConfig, RemoteConfig, RemoteLane};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::telemetry::{snapshot_line, StatsServer};
use infilter::train::TrainedModel;
use infilter::util::json::Json;
use infilter::util::prng::Pcg32;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Instant;

const N_STREAMS: u64 = 6;
const CLIPS_PER_STREAM: u64 = 2;
const FRAMES: u64 = N_STREAMS * CLIPS_PER_STREAM * 2;

fn engine() -> CpuEngine {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    CpuEngine::with_clip(&plan, 1.0, 64, 2)
}

fn model() -> TrainedModel {
    TrainedModel::synthetic(11, 4, engine().n_filters(), 0.0, 1.0)
}

fn workload() -> Vec<FrameTask> {
    let mut out = Vec::new();
    for s in 0..N_STREAMS {
        let mut rng = Pcg32::substream(97, s);
        for clip in 0..CLIPS_PER_STREAM {
            for f in 0..2usize {
                out.push(FrameTask {
                    stream: s,
                    clip_seq: clip,
                    frame_idx: f,
                    data: (0..64).map(|_| (rng.normal() * 0.1) as f32).collect(),
                    label: (s % 4) as usize,
                    t_gen: Instant::now(),
                });
            }
        }
    }
    out
}

fn spawn_node(m: TrainedModel) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let fp = m.fingerprint();
    let handle = std::thread::spawn(move || {
        serve_node(
            listener,
            pipeline_factory(engine(), m, 64),
            fp,
            NodeConfig::default(),
            Some(1),
        )
        .expect("node serving");
    });
    (addr, handle)
}

/// One real HTTP GET against the stats endpoint; returns the body.
fn scrape(addr: SocketAddr) -> String {
    let mut conn = TcpStream::connect(addr).expect("connect stats endpoint");
    write!(conn, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
    resp.split("\r\n\r\n").nth(1).expect("body").to_string()
}

/// The value on the exposition line whose first token is exactly
/// `name` (None when the family has not been registered yet).
fn metric(body: &str, name: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        let mut it = l.split_whitespace();
        (it.next() == Some(name)).then(|| it.next().unwrap().parse().unwrap())
    })
}

#[test]
fn scrape_mid_serve_sees_counters_advance() {
    let server = StatsServer::bind("127.0.0.1:0").unwrap();
    let base = scrape(server.addr());
    let base_frames = metric(&base, "node_frames_total").unwrap_or(0.0);
    let base_results = metric(&base, "node_results_total").unwrap_or(0.0);
    let base_sent = metric(&base, "gateway_frames_sent_total").unwrap_or(0.0);
    let base_rtt = metric(&base, "gateway_wire_rtt_us_count").unwrap_or(0.0);

    let m = model();
    let (addr, node) = spawn_node(m.clone());
    let mut lane = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();

    // connected, nothing served yet: pre-registration means every
    // required family is already scrapeable (at zero), and the live
    // session is visible
    let mid = scrape(server.addr());
    for family in [
        "node_sessions_live",
        "node_sessions_total",
        "node_busy_rejects_total",
        "node_handshake_failures_total",
        "node_frames_total",
        "node_results_total",
        "gateway_frames_sent_total",
        "gateway_queue_depth",
        "gateway_credit_stalls_total",
        "gateway_reconnects_total",
        "gateway_reroutes_total",
        "gateway_wire_rtt_us_count",
        "gateway_credit_stall_us_count",
    ] {
        assert!(
            metric(&mid, family).is_some(),
            "family '{family}' missing from mid-serve scrape:\n{mid}"
        );
    }
    let live_before = metric(&mid, "node_sessions_live").unwrap();
    assert!(live_before >= 1.0, "our session must be live: {live_before}");

    for t in workload() {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();

    // still serving (lane open), after the workload: counters advanced
    let after = scrape(server.addr());
    let d = |name: &str, base: f64| metric(&after, name).unwrap() - base;
    assert!(d("node_frames_total", base_frames) >= FRAMES as f64);
    assert!(d("node_results_total", base_results) >= (N_STREAMS * CLIPS_PER_STREAM) as f64);
    assert!(d("gateway_frames_sent_total", base_sent) >= FRAMES as f64);
    assert!(
        d("gateway_wire_rtt_us_count", base_rtt) >= 1.0,
        "the drain barrier is a measured wire round trip"
    );
    // node-side per-stage pipeline histograms fill on the same frames
    assert!(metric(&after, "pipeline_queue_wait_us_count").unwrap() >= FRAMES as f64);
    assert!(metric(&after, "pipeline_compute_us_count").unwrap() >= 1.0);

    let (report, results) = lane.finish().unwrap();
    node.join().unwrap();
    assert_eq!(results.len(), (N_STREAMS * CLIPS_PER_STREAM) as usize);
    assert_eq!(report.clips_classified, N_STREAMS * CLIPS_PER_STREAM);

    // session over: the live gauge stepped back down
    let done = scrape(server.addr());
    assert_eq!(
        metric(&done, "node_sessions_live").unwrap(),
        live_before - 1.0
    );
    server.stop();
}

#[test]
fn snapshot_jsonl_matches_the_documented_schema() {
    // the exact line `--stats-every` emits, validated the same way the
    // CI smoke step does: one JSON object, t_s number, metrics object
    // with counters as numbers and histograms as percentile summaries
    infilter::telemetry::registry()
        .counter("telemetry_stats_test_total")
        .add(3);
    infilter::telemetry::registry()
        .hist("telemetry_stats_test_us")
        .record_us(250.0);
    let line = snapshot_line(7.5);
    assert!(!line.contains('\n'), "one object per line");
    let j = Json::parse(&line).expect("snapshot line parses");
    assert_eq!(j.get("t_s").as_f64(), Some(7.5));
    let metrics = j.get("metrics");
    assert!(metrics.as_obj().is_some());
    assert!(metrics.get("telemetry_stats_test_total").as_f64().unwrap() >= 3.0);
    let h = metrics.get("telemetry_stats_test_us");
    for key in ["count", "mean_us", "p50_us", "p95_us", "p99_us", "max_us"] {
        assert!(
            h.get(key).as_f64().is_some(),
            "histogram snapshot missing '{key}': {line}"
        );
    }
}
