//! Documentation integrity, tier-1: (1) every relative cross-reference
//! in README.md, DESIGN.md and docs/*.md resolves — target file exists
//! and, when an `#anchor` is given, a heading with that GitHub-style
//! slug exists in the target; (2) docs/WIRE.md (the normative wire
//! spec) names every message variant of `net::proto::Msg`, so the spec
//! cannot silently fall behind the protocol. CI runs this via the
//! normal test suite and the docs job.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};

/// Repo root: the rust package lives one level below it.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

/// The markdown set under the cross-reference contract.
fn doc_files() -> Vec<PathBuf> {
    let root = repo_root();
    let mut files = vec![root.join("README.md"), root.join("DESIGN.md")];
    let docs = root.join("docs");
    if let Ok(entries) = fs::read_dir(&docs) {
        for e in entries.flatten() {
            let p = e.path();
            if p.extension().is_some_and(|x| x == "md") {
                files.push(p);
            }
        }
    }
    assert!(
        files.iter().filter(|p| p.starts_with(&docs)).count() >= 2,
        "docs/WIRE.md and docs/OPERATIONS.md are expected to exist"
    );
    files
}

/// GitHub-style heading slug: lowercase, backticks stripped, anything
/// that is not alphanumeric/space/hyphen/underscore removed, spaces
/// hyphenated.
fn slug(heading: &str) -> String {
    let mut s = String::new();
    for c in heading.trim().chars() {
        let c = c.to_ascii_lowercase();
        match c {
            '`' => {}
            'a'..='z' | '0'..='9' | '_' | '-' => s.push(c),
            ' ' => s.push('-'),
            _ => {}
        }
    }
    s
}

/// Every heading slug in one markdown file (fenced code blocks are
/// excluded so a `# comment` inside ```sh does not count).
fn heading_slugs(text: &str) -> HashSet<String> {
    let mut slugs = HashSet::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let title = rest.trim_start_matches('#');
            slugs.insert(slug(title));
        }
    }
    slugs
}

/// Inline markdown links `[text](target)` outside fenced code blocks.
fn links(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let bytes = line.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            if bytes[i] == b']' && i + 1 < bytes.len() && bytes[i + 1] == b'(' {
                if let Some(end) = line[i + 2..].find(')') {
                    out.push(line[i + 2..i + 2 + end].to_string());
                    i += 2 + end;
                    continue;
                }
            }
            i += 1;
        }
    }
    out
}

#[test]
fn every_relative_doc_link_resolves() {
    let mut checked = 0usize;
    for file in doc_files() {
        let text = fs::read_to_string(&file)
            .unwrap_or_else(|e| panic!("reading {}: {e}", file.display()));
        let dir = file.parent().unwrap().to_path_buf();
        for link in links(&text) {
            if link.starts_with("http://") || link.starts_with("https://") {
                continue; // external links are out of scope (offline CI)
            }
            let (path_part, anchor) = match link.split_once('#') {
                Some((p, a)) => (p, Some(a.to_string())),
                None => (link.as_str(), None),
            };
            let target = if path_part.is_empty() {
                file.clone() // same-file anchor
            } else {
                dir.join(path_part)
            };
            assert!(
                target.exists(),
                "{}: broken link `{link}` (missing {})",
                file.display(),
                target.display()
            );
            if let Some(anchor) = anchor {
                let ttext = fs::read_to_string(&target)
                    .unwrap_or_else(|e| panic!("reading {}: {e}", target.display()));
                let slugs = heading_slugs(&ttext);
                assert!(
                    slugs.contains(&anchor),
                    "{}: link `{link}` names anchor `#{anchor}` but {} has \
                     headings {slugs:?}",
                    file.display(),
                    target.display()
                );
            }
            checked += 1;
        }
    }
    assert!(
        checked >= 8,
        "the doc set is expected to be cross-linked (found {checked} links)"
    );
}

#[test]
fn wire_spec_covers_every_protocol_message() {
    let root = repo_root();
    let proto = fs::read_to_string(root.join("rust/src/net/proto.rs")).unwrap();
    // variants of `pub enum Msg`, parsed from the source so the list
    // cannot drift from the real protocol
    let body = proto
        .split("pub enum Msg {")
        .nth(1)
        .expect("proto.rs defines `pub enum Msg`");
    let body = &body[..body.find("\n}").expect("enum body ends")];
    let mut variants = Vec::new();
    for line in body.lines() {
        let t = line.trim();
        if t.starts_with("///") || t.starts_with("//") || t.is_empty() {
            continue;
        }
        // a variant line starts with a capitalised identifier
        let name: String = t
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric())
            .collect();
        if !name.is_empty() && name.chars().next().unwrap().is_ascii_uppercase() {
            variants.push(name);
        }
    }
    assert!(
        variants.len() >= 11,
        "expected the full message set, parsed {variants:?}"
    );
    let wire = fs::read_to_string(root.join("docs/WIRE.md")).unwrap();
    for v in &variants {
        assert!(
            wire.contains(v),
            "docs/WIRE.md does not mention protocol message `{v}` — the \
             spec fell behind rust/src/net/proto.rs"
        );
    }
    // and the spec's stated version matches the code
    let version_line = proto
        .lines()
        .find(|l| l.starts_with("pub const VERSION"))
        .expect("proto.rs declares VERSION");
    let code_version: u32 = version_line
        .split('=')
        .nth(1)
        .unwrap()
        .trim()
        .trim_end_matches(';')
        .parse()
        .expect("numeric VERSION");
    assert!(
        wire.contains(&format!("Protocol version: **{code_version}**")),
        "docs/WIRE.md's stated protocol version is out of date \
         (code is v{code_version})"
    );
}

/// Parse the variant names of one `pub enum` out of a source file, the
/// same way [`wire_spec_covers_every_protocol_message`] parses `Msg`.
fn enum_variants(source: &str, enum_name: &str) -> Vec<String> {
    let marker = format!("pub enum {enum_name} {{");
    let body = source
        .split(marker.as_str())
        .nth(1)
        .unwrap_or_else(|| panic!("source defines `pub enum {enum_name}`"));
    let body = &body[..body.find("\n}").expect("enum body ends")];
    let mut variants = Vec::new();
    for line in body.lines() {
        let t = line.trim();
        if t.starts_with("///") || t.starts_with("//") || t.is_empty() {
            continue;
        }
        let name: String = t
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric())
            .collect();
        if !name.is_empty() && name.chars().next().unwrap().is_ascii_uppercase() {
            variants.push(name);
        }
    }
    variants
}

/// CamelCase → kebab-case, mirroring `Invariant::name` in the checker.
fn kebab(ident: &str) -> String {
    let mut out = String::new();
    for c in ident.chars() {
        if c.is_ascii_uppercase() && !out.is_empty() {
            out.push('-');
        }
        out.push(c.to_ascii_lowercase());
    }
    out
}

/// docs/WIRE.md must name every state of the three executable-spec
/// machines and every invariant `verify-proto` checks — parsed from the
/// model sources, so the prose cannot silently fall behind the spec the
/// checker actually explores.
#[test]
fn wire_spec_covers_every_spec_machine_state_and_checked_invariant() {
    let root = repo_root();
    let wire = fs::read_to_string(root.join("docs/WIRE.md")).unwrap();
    let spec = fs::read_to_string(root.join("rust/src/net/model/spec.rs")).unwrap();
    for machine in ["LaneState", "NodeState", "CreditState"] {
        let variants = enum_variants(&spec, machine);
        assert!(
            variants.len() >= 3,
            "expected the full {machine} state set, parsed {variants:?}"
        );
        for v in &variants {
            assert!(
                wire.contains(v),
                "docs/WIRE.md does not mention `{machine}::{v}` — the spec \
                 prose fell behind rust/src/net/model/spec.rs"
            );
        }
    }
    let checker = fs::read_to_string(root.join("rust/src/net/model/checker.rs")).unwrap();
    let invariants = enum_variants(&checker, "Invariant");
    assert!(
        invariants.len() >= 5,
        "expected the five checked invariants, parsed {invariants:?}"
    );
    for inv in &invariants {
        let name = kebab(inv);
        assert!(
            wire.contains(&name),
            "docs/WIRE.md does not name checked invariant `{name}` — the \
             spec prose fell behind rust/src/net/model/checker.rs"
        );
    }
}
