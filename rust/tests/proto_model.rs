//! Tier-1 acceptance for the wire-protocol model layer: the bounded
//! checker proves every WIRE.md invariant over the correct executable
//! spec, each deliberate spec mutation is caught by exactly the
//! invariant it breaks (with a minimal, deterministic counterexample
//! trace), and the [`ConformanceMonitor`] armed on a real loopback
//! session observes a clean trace end to end.
//!
//! The CI `model` job runs the same checks through the
//! `infilter verify-proto` subcommand; this file keeps them inside
//! `cargo test` so a regression is caught before any workflow runs.
//!
//! [`ConformanceMonitor`]: infilter::net::ConformanceMonitor

use infilter::coordinator::dispatch::Lane;
use infilter::coordinator::FrameTask;
use infilter::dsp::multirate::BandPlan;
use infilter::net::model::{check, CheckConfig, FaultEvent, Invariant, Mutation};
use infilter::net::node::pipeline_factory;
use infilter::net::{serve_node_until, NodeConfig, NodeShutdown, RemoteConfig, RemoteLane};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::time::Instant;

// ---------------------------------------------------------------------
// the checker against the correct spec
// ---------------------------------------------------------------------

/// The CI-depth exploration: every fault kind available, every
/// invariant armed, and the whole bounded space expanded (`complete`),
/// so a pass is an exhaustive proof within the bounds, not a sample.
#[test]
fn exhaustive_check_proves_all_invariants_at_ci_depth() {
    let out = check(&CheckConfig::default());
    assert!(
        out.violation.is_none(),
        "the correct spec must satisfy every invariant:\n{}",
        out.violation.unwrap()
    );
    assert!(
        out.complete,
        "the default bounds must cover the space exhaustively \
         ({} truncated)",
        out.stats.truncated
    );
    assert!(out.stats.terminal_states > 0, "no execution ever finished");
    assert!(
        out.stats.states_explored > 1_000,
        "implausibly small space ({}) — did fault interleaving collapse?",
        out.stats.states_explored
    );
}

/// Which invariant each deliberate spec break trips. This is the
/// checker checking itself: a model checker that cannot find a planted
/// bug proves nothing by passing.
#[test]
fn every_mutation_is_caught_by_its_invariant() {
    let expected = [
        (Mutation::DropCreditGrant, Invariant::DeadlockFreedom),
        (Mutation::DoubleGrant, Invariant::CreditConservation),
        (Mutation::SkipDrainClassify, Invariant::DrainCompleteness),
        (Mutation::FlushAlwaysPads, Invariant::FlushIdempotence),
    ];
    for (mutation, invariant) in expected {
        let cfg = CheckConfig {
            mutation,
            ..CheckConfig::default()
        };
        let out = check(&cfg);
        let cx = out.violation.unwrap_or_else(|| {
            panic!("mutation {} escaped the checker", mutation.name())
        });
        assert_eq!(
            cx.invariant,
            invariant,
            "mutation {} tripped {} instead of {}:\n{cx}",
            mutation.name(),
            cx.invariant.name(),
            invariant.name()
        );
        assert!(
            !cx.trace.is_empty(),
            "a counterexample must carry its reproducing trace"
        );
    }
}

/// The stale-results mutation needs a death to replay across, so it is
/// driven by the crash faults rather than the full pool.
#[test]
fn stale_results_mutation_breaks_death_accounting() {
    let cfg = CheckConfig {
        mutation: Mutation::StaleResults,
        faults: vec![FaultEvent::CrashMidCompute, FaultEvent::Drop],
        ..CheckConfig::default()
    };
    let out = check(&cfg);
    let cx = out.violation.expect("stale results escaped the checker");
    assert_eq!(cx.invariant, Invariant::DeathAccounting, "{cx}");
}

/// BFS order is deterministic, so the minimal counterexample is a
/// stable artifact: two runs print the identical trace. OPERATIONS.md's
/// replay walkthrough depends on this.
#[test]
fn counterexample_traces_are_minimal_and_deterministic() {
    let cfg = CheckConfig {
        mutation: Mutation::DropCreditGrant,
        faults: Vec::new(),
        ..CheckConfig::default()
    };
    let a = check(&cfg).violation.expect("deadlock not found");
    let b = check(&cfg).violation.expect("deadlock not found on rerun");
    assert_eq!(a.trace, b.trace, "the minimal trace must be reproducible");
    assert_eq!(a.invariant, Invariant::DeadlockFreedom);
}

/// `--invariant` masks everything else: with only flush-idempotence
/// armed, the double-grant bug must *not* be reported.
#[test]
fn invariant_filter_scopes_the_check() {
    let cfg = CheckConfig {
        mutation: Mutation::DoubleGrant,
        invariants: vec![Invariant::FlushIdempotence],
        ..CheckConfig::default()
    };
    assert!(
        check(&cfg).violation.is_none(),
        "a masked invariant must not fail the run"
    );
}

// ---------------------------------------------------------------------
// the monitor against a real session
// ---------------------------------------------------------------------

fn engine() -> CpuEngine {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    CpuEngine::with_clip(&plan, 1.0, 64, 2)
}

/// A full loopback session — connect, stream, drain, flush, finish —
/// with the conformance monitor armed from the start: the production
/// lane's observable trace must be one the spec machines produce, so
/// the monitor log stays empty and the invariant-violation counter
/// stays flat.
#[test]
fn loopback_session_is_conformant_under_the_monitor() {
    let m = TrainedModel::synthetic(5, 3, engine().n_filters(), 0.0, 1.0);
    let fp = m.fingerprint();
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let stop = NodeShutdown::new();
    let node = std::thread::spawn({
        let (stop, m) = (stop.clone(), m.clone());
        move || {
            serve_node_until(
                listener,
                pipeline_factory(engine(), m, 64),
                fp,
                NodeConfig { credits: 4, ..NodeConfig::default() },
                None,
                stop,
            )
            .expect("node serving");
        }
    });

    let mut lane = RemoteLane::connect(&addr, fp, RemoteConfig::default()).expect("connect");
    let log = lane.arm_monitor();
    let mut rng = Pcg32::new(0xC0F0);
    for stream in 0..3u64 {
        for clip in 0..2u64 {
            for f in 0..2usize {
                assert!(lane.push(FrameTask {
                    stream,
                    clip_seq: clip,
                    frame_idx: f,
                    data: (0..64).map(|_| (rng.normal() * 0.1) as f32).collect(),
                    label: (stream % 3) as usize,
                    t_gen: Instant::now(),
                }));
            }
        }
    }
    lane.drain().expect("drain barrier");
    // a stranded half clip exercises the flush path under the monitor
    assert!(lane.push(FrameTask {
        stream: 9,
        clip_seq: 0,
        frame_idx: 0,
        data: vec![0.01; 64],
        label: 0,
        t_gen: Instant::now(),
    }));
    let flushed = lane.flush_tails().expect("flush barrier");
    assert_eq!(flushed, 1, "the stranded tail is padded exactly once");
    let (report, results) = lane.finish().expect("finish");
    stop.shutdown();
    node.join().unwrap();

    assert!(
        log.is_clean(),
        "conformance monitor diverged on a clean session:\n  {}",
        log.divergences().join("\n  ")
    );
    assert_eq!(report.clips_classified, 7, "6 full clips + 1 padded tail");
    assert_eq!(results.len(), 7);
}
