//! Cross-module integration tests over the real AOT artifacts: the
//! full train -> save -> load -> serve -> classify loop, figure/table
//! harness smoke runs, and float/fixed/HLO cross-validation.
//!
//! All tests no-op gracefully when artifacts/ has not been built
//! (`make artifacts`), so `cargo test` works in a fresh checkout.

use infilter::coordinator::server::{serve, ServeConfig};
use infilter::datasets::esc10;
use infilter::experiments::{classify, figures, tables12};
use infilter::mp::machine::Standardizer;
use infilter::runtime::engine::ModelEngine;
use infilter::train::{evaluate, train_model, TrainConfig, TrainedModel};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn full_loop_train_save_load_serve() {
    let Some(dir) = artifacts() else { return };
    let mut eng = ModelEngine::open(&dir, 1.0).unwrap();
    let clip_len = eng.frame_len() * eng.clip_frames();

    // train a small multiclass model
    let ds = esc10::build(5, 0.04);
    let samps: Vec<&[f32]> = ds.train.iter().map(|c| &c.samples[..clip_len]).collect();
    let phi = eng.clip_features_many(&samps).unwrap();
    let labels: Vec<usize> = ds.train.iter().map(|c| c.label).collect();
    // at this tiny scale each epoch is one SGD step; give the 10-way
    // model enough steps to clear chance level
    let cfg = TrainConfig {
        epochs: 80,
        lr: 0.3,
        ..TrainConfig::default()
    };
    let (model, losses) =
        train_model(&mut eng, &phi, &labels, &ds.classes, 1.0, &cfg).unwrap();
    assert!(losses.last().unwrap() <= &losses[0]);

    // save -> load roundtrip
    let path = std::env::temp_dir().join("infilter_it_model.json");
    model.save(&path).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    assert_eq!(loaded.params, model.params);
    std::fs::remove_file(&path).ok();

    // serve with the loaded model: all clips classified, stream math
    // identical to the offline path (checked inside server tests too)
    let scfg = ServeConfig {
        n_streams: 4,
        clips_per_stream: 1,
        seed: 3,
        ..Default::default()
    };
    let (report, results) = serve(&mut eng, &loaded, &scfg).unwrap();
    assert_eq!(report.clips_classified, 4);
    assert_eq!(results.len(), 4);

    // evaluation path still works post-roundtrip
    let acc = evaluate(&mut eng, &loaded, &phi, &labels).unwrap();
    assert!(acc > 0.25, "sanity: clearly better than 10% chance, got {acc}");
}

#[test]
fn hlo_float_rust_float_and_fixed_agree_on_ranking() {
    let Some(dir) = artifacts() else { return };
    let mut eng = ModelEngine::open(&dir, 1.0).unwrap();
    let clip_len = eng.frame_len() * eng.clip_frames();
    // one clip, three feature paths
    let clip = esc10::synth_clip(9, 2, 0); // sea_waves: low-band heavy
    let samples = &clip.samples[..clip_len];
    let hlo = eng.clip_features(samples).unwrap();
    let rust = infilter::features::mp_features(&eng.plan, 1.0, samples);
    // HLO and rust float match closely
    for (i, (a, b)) in hlo.iter().zip(&rust).enumerate() {
        assert!(
            (a - b).abs() / b.abs().max(1.0) < 5e-3,
            "band {i}: {a} vs {b}"
        );
    }
    // fixed 10-bit accumulators correlate strongly with float
    let pipe = infilter::fixed::FixedPipeline::build(
        &eng.plan,
        1.0,
        4.0,
        &infilter::mp::machine::Params::zeros(2, 30),
        &Standardizer {
            mu: vec![0.0; 30],
            sigma: vec![1.0; 30],
        },
        &[hlo.clone()],
        infilter::fixed::FixedConfig::with_bits(10),
    );
    let acc = pipe.accumulate(samples);
    let fmt = pipe.datapath_format();
    let dot: f64 = acc
        .iter()
        .zip(&hlo)
        .map(|(&q, &f)| fmt.dequantize(q) * f64::from(f))
        .sum();
    let na: f64 = acc.iter().map(|&q| fmt.dequantize(q).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = hlo.iter().map(|&f| f64::from(f).powi(2)).sum::<f64>().sqrt();
    assert!(dot / (na * nb) > 0.98, "cos {}", dot / (na * nb));
}

#[test]
fn table3_harness_smoke() {
    let Some(dir) = artifacts() else { return };
    let mut eng = ModelEngine::open(&dir, 1.0).unwrap();
    let ds = esc10::build(7, 0.03);
    let ccfg = classify::ClassifyConfig {
        seed: 7,
        threads: 8,
        train_cfg: TrainConfig {
            epochs: 8,
            ..TrainConfig::default()
        },
        ..Default::default()
    };
    let bank = classify::extract_features(&mut eng, &ds, &ccfg).unwrap();
    let (t, rows) = classify::run_table(&mut eng, &ds, &bank, &ccfg).unwrap();
    assert_eq!(rows.len(), 10);
    assert_eq!(t.rows.len(), 11); // 10 classes + MEAN
    for r in &rows {
        for acc in [
            r.svm_train, r.svm_test, r.car_train, r.car_test,
            r.mp_train, r.mp_test, r.fx_train, r.fx_test,
        ] {
            assert!((0.0..=1.0).contains(&acc), "{r:?}");
        }
        assert!(r.svs > 0);
    }
}

#[test]
fn cpu_kernel_matches_exact_reference_no_artifacts() {
    // PR 3 acceptance, artifact-free: the shared block kernel the
    // serving path runs (mp::kernel) against the verbatim sort-based
    // reference, on the full paper plan with streaming state
    use infilter::runtime::backend::{CpuEngine, InferenceBackend};
    let plan = infilter::dsp::multirate::BandPlan::paper_default();
    let mut eng = CpuEngine::new(&plan, 1.0);
    let clip = esc10::synth_clip(2, 4, 9);
    let frame = &clip.samples[..2048];
    let mut st_new = eng.zero_state();
    let mut st_old = eng.zero_state();
    let phi_new = eng.mp_frame_features(&mut st_new, frame).unwrap();
    let phi_old = eng.frame_features_exact(&mut st_old, frame);
    assert_eq!(st_new, st_old, "delay-line state must carry identically");
    for (i, (a, b)) in phi_new.iter().zip(&phi_old).enumerate() {
        let denom = b.abs().max(1.0);
        assert!((a - b).abs() / denom < 5e-3, "band {i}: new {a} old {b}");
    }
}

#[test]
fn figure_harnesses_produce_csvs() {
    let plan = infilter::dsp::multirate::BandPlan::paper_default();
    let (ta, _) = figures::fig4a(&plan, 4096);
    let (tb, _) = figures::fig4b(&plan, 4096);
    let (tc, _, corr) = figures::fig6(&plan, 1.0, 4096);
    assert_eq!(ta.rows.len(), tb.rows.len());
    assert_eq!(tc.header.len(), 31);
    assert_eq!(corr.len(), 30);
    // CSV serialisation round-trips through the table writer
    let csv = ta.to_csv();
    assert!(csv.lines().count() > 100);
}

#[test]
fn table12_consistent_with_fpga_model() {
    let (t1, detail1) = tables12::table1();
    let (t2, _) = tables12::table2();
    // Table II "this work (model)" row must quote the same numbers as
    // Table I
    let ff_t1: String = t1.rows[4][1].clone();
    let lut_t1: String = t1.rows[5][1].clone();
    let ours = t2.rows.last().unwrap();
    assert_eq!(ours[4], ff_t1);
    assert_eq!(ours[5], lut_t1);
    assert!(detail1.contains("schedulable=true"));
}

#[test]
fn cli_binary_usage_and_fpga_sim() {
    // run the actual binary: usage text + the fpga-sim subcommand
    let bin = env!("CARGO_BIN_EXE_infilter");
    let out = std::process::Command::new(bin).output().unwrap();
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
    let out = std::process::Command::new(bin)
        .arg("fpga-sim")
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("schedulable=true"), "{text}");
}
