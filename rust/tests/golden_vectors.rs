//! Golden-vector acceptance for the fixed-point datapath
//! (tests/golden/README.md).
//!
//! `tests/golden/manifest.json` pins a deterministic fixture set; the
//! expected integer feature rows / margins / decisions live in
//! `tests/golden/expected.json`, blessed by this suite on first run
//! (delete the file to regenerate deliberately). Two independent
//! properties are enforced:
//!
//! 1. **Reference stability** — `fixed::FixedPipeline` reproduces the
//!    blessed vectors bit-exactly; any drift is a datapath change and
//!    fails loudly with the offending clip and stage.
//! 2. **Serving parity** — `runtime::fixed::FixedEngine`, driven
//!    frame-by-frame through the allocation-free `*_into` surface the
//!    way `Pipeline::tick` drives it, matches the clip-level reference
//!    bit-identically after *every* frame (prefix accumulate), and its
//!    inference output matches `FixedPipeline::classify` to the bit.
//!    This holds even before a bless, so a fresh checkout is guarded.

use infilter::dsp::multirate::BandPlan;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::mp::filter::MpMultirateBank;
use infilter::mp::machine::{Params, Standardizer};
use infilter::runtime::backend::InferenceBackend;
use infilter::runtime::fixed::FixedEngine;
use infilter::util::json::Json;
use infilter::util::prng::Pcg32;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn load_json(name: &str) -> Option<Json> {
    let path = golden_dir().join(name);
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).unwrap_or_else(|e| panic!("{}: invalid JSON: {e:?}", path.display())))
}

/// The calibrated pipeline every golden clip runs through — the same
/// deterministic toy setup the `fixed::kernel` unit tests use, so a
/// golden failure here and a kernel failure there point at the same
/// datapath.
fn golden_pipe(bits: u32) -> (BandPlan, FixedPipeline) {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 3;
    let mut rng = Pcg32::new(7);
    let feats = plan.n_filters();
    let params = Params {
        wp: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        wm: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        bp: vec![0.1, -0.2],
        bm: vec![-0.1, 0.2],
    };
    let mut bank = MpMultirateBank::new(&plan, 1.0);
    let phis: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            bank.reset();
            let clip: Vec<f32> = Pcg32::new(100 + i)
                .normal_vec(2048)
                .iter()
                .map(|x| 0.3 * x)
                .collect();
            bank.features(&clip)
        })
        .collect();
    let std = Standardizer::fit(&phis);
    let pipe = FixedPipeline::build(
        &plan,
        1.0,
        4.0,
        &params,
        &std,
        &phis,
        FixedConfig::with_bits(bits),
    );
    (plan, pipe)
}

/// Regenerate one fixture clip from its manifest spec. Everything is
/// seeded through the repo's own `Pcg32`; no ambient entropy.
fn clip_from_spec(spec: &Json, len: usize, sample_rate: f64) -> Vec<f32> {
    let kind = spec.get("kind").as_str().expect("clip kind");
    let seed = spec.get("seed").as_f64().expect("clip seed") as u64;
    let amp = spec.get("amp").as_f64().expect("clip amp");
    let freq = spec.get("freq").as_f64().expect("clip freq");
    let tone = |a: f64| -> Vec<f32> {
        (0..len)
            .map(|i| (a * (2.0 * std::f64::consts::PI * freq * i as f64 / sample_rate).sin()) as f32)
            .collect()
    };
    let noise = |a: f64| -> Vec<f32> {
        Pcg32::new(seed).normal_vec(len).iter().map(|x| (a * f64::from(*x)) as f32).collect()
    };
    match kind {
        "noise" => noise(amp),
        "tone" => tone(amp),
        "mix" => {
            let t = tone(amp);
            noise(amp * 0.5).iter().zip(&t).map(|(n, t)| n + t).collect()
        }
        other => panic!("unknown clip kind {other:?} in manifest"),
    }
}

/// What the reference pipeline produces for one clip — the unit the
/// expected file stores and the engine must reproduce.
struct GoldenRow {
    name: String,
    acc: Vec<i64>,
    k: Vec<i64>,
    /// per head: (margin, z+, z-)
    margins: Vec<(i64, i64, i64)>,
    decision: usize,
}

fn argmax_margin(margins: &[(i64, i64, i64)]) -> usize {
    let mut best = 0usize;
    for (i, m) in margins.iter().enumerate() {
        if m.0 > margins[best].0 {
            best = i;
        }
    }
    best
}

fn reference_row(pipe: &FixedPipeline, name: &str, clip: &[f32]) -> GoldenRow {
    let acc = pipe.accumulate(clip);
    let k = pipe.standardize(&acc);
    let margins = pipe.infer_full(&k);
    let decision = argmax_margin(&margins);
    GoldenRow {
        name: name.to_string(),
        acc,
        k,
        margins,
        decision,
    }
}

fn i64s(xs: &[i64]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn row_to_json(r: &GoldenRow) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("acc", i64s(&r.acc)),
        ("k", i64s(&r.k)),
        (
            "margins",
            Json::Arr(
                r.margins
                    .iter()
                    .map(|&(m, zp, zm)| i64s(&[m, zp, zm]))
                    .collect(),
            ),
        ),
        ("decision", Json::Num(r.decision as f64)),
    ])
}

fn json_to_i64s(j: &Json, what: &str, clip: &str) -> Vec<i64> {
    j.as_arr()
        .unwrap_or_else(|| panic!("expected.json: {clip}/{what} is not an array"))
        .iter()
        .map(|v| {
            let f = v.as_f64().unwrap_or_else(|| panic!("expected.json: {clip}/{what} non-number"));
            // every stored value sits far inside f64's exact-integer
            // window (the prover caps registers at < 2^31)
            f as i64
        })
        .collect()
}

fn assert_row_matches(expected: &Json, got: &GoldenRow) {
    let clip = &got.name;
    assert_eq!(
        expected.get("name").as_str(),
        Some(clip.as_str()),
        "expected.json clip order drifted from manifest.json"
    );
    assert_eq!(
        json_to_i64s(expected.get("acc"), "acc", clip),
        got.acc,
        "[golden {clip}] accumulated feature row drifted from the blessed vector \
         (datapath change? delete tests/golden/expected.json to re-bless deliberately)"
    );
    assert_eq!(
        json_to_i64s(expected.get("k"), "k", clip),
        got.k,
        "[golden {clip}] standardized feature row drifted from the blessed vector"
    );
    let margins: Vec<(i64, i64, i64)> = expected
        .get("margins")
        .as_arr()
        .unwrap_or_else(|| panic!("expected.json: {clip}/margins missing"))
        .iter()
        .map(|t| {
            let v = json_to_i64s(t, "margins", clip);
            assert_eq!(v.len(), 3, "[golden {clip}] margin triple arity");
            (v[0], v[1], v[2])
        })
        .collect();
    assert_eq!(
        margins, got.margins,
        "[golden {clip}] inference margins drifted from the blessed vector"
    );
    assert_eq!(
        expected.get("decision").as_usize(),
        Some(got.decision),
        "[golden {clip}] decision drifted from the blessed vector"
    );
}

fn dummy_params() -> (Params, Standardizer) {
    (
        Params {
            wp: vec![],
            wm: vec![],
            bp: vec![],
            bm: vec![],
        },
        Standardizer {
            mu: vec![],
            sigma: vec![],
        },
    )
}

#[test]
fn golden_vectors_pin_the_fixed_datapath_and_the_serving_engine() {
    let manifest = load_json("manifest.json").expect("tests/golden/manifest.json is committed");
    let bits = manifest.get("bits").as_usize().expect("manifest bits") as u32;
    let acc_bits = manifest.get("acc_bits").as_usize().expect("manifest acc_bits") as u32;
    let frame_len = manifest.get("frame_len").as_usize().expect("manifest frame_len");
    let clip_len = manifest.get("clip_len").as_usize().expect("manifest clip_len");
    assert_eq!(clip_len % frame_len, 0, "manifest clip/frame geometry");
    let clip_frames = clip_len / frame_len;

    let (plan, pipe) = golden_pipe(bits);
    let specs = manifest.get("clips").as_arr().expect("manifest clips").to_vec();
    assert!(!specs.is_empty(), "manifest has no clips");

    // ---- reference rows for every fixture clip
    let rows: Vec<(Vec<f32>, GoldenRow)> = specs
        .iter()
        .map(|spec| {
            let name = spec.get("name").as_str().expect("clip name");
            let clip = clip_from_spec(spec, clip_len, plan.sample_rate);
            let row = reference_row(&pipe, name, &clip);
            (clip, row)
        })
        .collect();

    // ---- serving parity: always enforced, needs no blessed file.
    // The engine is constructed through its certification gate and
    // driven exactly the way Pipeline::tick drives a backend.
    let mut eng = FixedEngine::new(pipe.clone(), frame_len, clip_frames, acc_bits)
        .expect("the golden configuration certifies");
    let (params, std) = dummy_params();
    let p = eng.n_filters();
    for (clip, row) in &rows {
        let clip_name = &row.name;
        let mut st = eng.zero_state();
        let mut acc = vec![0.0f32; p];
        let mut phi = vec![0.0f32; p];
        for (fi, frame) in clip.chunks(frame_len).enumerate() {
            eng.mp_frame_features_into(&mut st, frame, &mut phi).unwrap();
            for (a, v) in acc.iter_mut().zip(&phi) {
                *a += v;
            }
            // frame-level golden check: after frame fi the engine's
            // running accumulator equals the reference pipeline run on
            // the clip prefix — bit-exact, not approximately
            let prefix = pipe.accumulate(&clip[..(fi + 1) * frame_len]);
            let got: Vec<i64> = acc.iter().map(|&v| v as i64).collect();
            assert!(
                acc.iter().all(|v| v.fract() == 0.0),
                "[golden {clip_name}] frame {fi}: Phi left the exact-integer window"
            );
            assert_eq!(
                got, prefix,
                "[golden {clip_name}] frame {fi}: engine features diverged from the \
                 clip-prefix reference"
            );
        }
        let (pv, zp, zm) = eng.inference(&params, &std, &acc, 1.0).unwrap();
        let reference = pipe.classify(clip);
        assert_eq!(pv.len(), reference.len());
        for (h, (a, b)) in pv.iter().zip(&reference).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "[golden {clip_name}] head {h}: engine margin {a} != reference {b}"
            );
        }
        // the engine's (z+, z-) must be the dequantized infer_full pair
        let k_fmt = pipe.feature_format();
        for (h, &(_, rzp, rzm)) in row.margins.iter().enumerate() {
            assert_eq!(
                zp[h].to_bits(),
                (k_fmt.dequantize(rzp) as f32).to_bits(),
                "[golden {clip_name}] z+ head {h}"
            );
            assert_eq!(
                zm[h].to_bits(),
                (k_fmt.dequantize(rzm) as f32).to_bits(),
                "[golden {clip_name}] z- head {h}"
            );
        }
    }

    // ---- blessed-vector stability
    match load_json("expected.json") {
        None => {
            let blessed = Json::obj(vec![
                ("bits", Json::Num(f64::from(bits))),
                ("acc_bits", Json::Num(f64::from(acc_bits))),
                (
                    "clips",
                    Json::Arr(rows.iter().map(|(_, r)| row_to_json(r)).collect()),
                ),
            ]);
            let path = golden_dir().join("expected.json");
            std::fs::write(&path, blessed.to_string_pretty())
                .unwrap_or_else(|e| panic!("blessing {}: {e}", path.display()));
            eprintln!(
                "golden: blessed {} with {} clip(s) — commit it; later runs enforce it bit-exactly",
                path.display(),
                rows.len()
            );
        }
        Some(expected) => {
            assert_eq!(expected.get("bits").as_usize(), Some(bits as usize));
            assert_eq!(expected.get("acc_bits").as_usize(), Some(acc_bits as usize));
            let eclips = expected.get("clips").as_arr().expect("expected.json clips");
            assert_eq!(
                eclips.len(),
                rows.len(),
                "expected.json clip count drifted from manifest.json — delete it to re-bless"
            );
            for (e, (_, r)) in eclips.iter().zip(&rows) {
                assert_row_matches(e, r);
            }
        }
    }
}

/// The fixture set must exercise more than one decision path — all
/// clips landing on one head would make the decision pins vacuous.
#[test]
fn golden_fixtures_are_not_degenerate() {
    let manifest = load_json("manifest.json").expect("manifest");
    let bits = manifest.get("bits").as_usize().unwrap() as u32;
    let clip_len = manifest.get("clip_len").as_usize().unwrap();
    let (plan, pipe) = golden_pipe(bits);
    let mut nonzero_acc = 0usize;
    let mut margins_seen = std::collections::BTreeSet::new();
    for spec in manifest.get("clips").as_arr().unwrap() {
        let name = spec.get("name").as_str().unwrap();
        let clip = clip_from_spec(spec, clip_len, plan.sample_rate);
        let row = reference_row(&pipe, name, &clip);
        if row.acc.iter().any(|&v| v != 0) {
            nonzero_acc += 1;
        }
        margins_seen.insert(row.margins.iter().map(|m| m.0).collect::<Vec<_>>());
    }
    assert!(
        nonzero_acc >= 3,
        "most fixture clips produce empty feature rows — the golden pins are vacuous"
    );
    assert!(
        margins_seen.len() >= 2,
        "every fixture clip lands on identical margins — widen the fixture set"
    );
}
