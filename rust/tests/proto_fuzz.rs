//! Wire-decoder robustness properties (docs/WIRE.md §Framing): the
//! length-prefixed decoder must survive arbitrary bytes, truncation at
//! every offset, and single-bit corruption — returning a clean `Err`
//! (never panicking, never allocating past the `MAX_MSG_BYTES` cap) on
//! everything malformed. These are the same corruptions the chaos
//! proxy injects on a live socket (`tests/net_chaos.rs`); here they run
//! against in-memory cursors at property-test volume. A failing case
//! prints its seed for replay with `Gen::replay(seed)`.

use infilter::net::proto::{read_msg, write_msg, Handshake, Msg, RejectCode, MAX_MSG_BYTES};
use infilter::util::proptest::{check, Gen};
use std::io::Cursor;

/// A seeded valid message of a seeded variant — the corruption targets.
fn arbitrary_msg(g: &mut Gen) -> Msg {
    match g.usize(0, 6) {
        0 => Msg::Hello(Handshake::wildcard(g.rng.next_u64())),
        1 => {
            let n = g.usize(0, 64);
            Msg::Frame {
                stream: g.rng.next_u64(),
                clip_seq: g.rng.next_u64(),
                frame_idx: g.rng.next_u32(),
                label: g.rng.next_u32() % 16,
                samples: g.signal(n, 0.5),
            }
        }
        6 => {
            // the v4 quantized frame: delta-coded i16 samples; extreme
            // values exercise the predictor's escape paths
            let n = g.usize(0, 64);
            Msg::FrameQ {
                stream: g.rng.next_u64(),
                clip_seq: g.rng.next_u64(),
                frame_idx: g.rng.next_u32(),
                label: g.rng.next_u32() % 16,
                frac: g.int(1, 15) as u8,
                samples: (0..n).map(|_| g.int(-32768, 32767) as i16).collect(),
            }
        }
        2 => Msg::Credit { n: g.rng.next_u32() },
        3 => Msg::Drain {
            token: g.rng.next_u64(),
        },
        4 => Msg::Reject {
            code: RejectCode::Busy,
            reason: "chaos".to_string(),
        },
        _ => Msg::FlushAck {
            token: g.rng.next_u64(),
            flushed: g.rng.next_u64(),
        },
    }
}

/// One framed wire image of a valid message: `[u32 LE len][payload]`.
fn wire_image(g: &mut Gen) -> Vec<u8> {
    let msg = arbitrary_msg(g);
    let mut wire = Vec::new();
    let mut scratch = Vec::new();
    write_msg(&mut wire, &msg, &mut scratch).expect("valid messages encode");
    wire
}

#[test]
fn decode_of_arbitrary_bytes_never_panics() {
    check("proto-decode-arbitrary", 500, |g| {
        let n = g.usize(0, 256);
        let payload: Vec<u8> = (0..n).map(|_| g.rng.next_u32() as u8).collect();
        // Ok (the bytes happened to form a message) and Err are both
        // fine; the property is that decode returns at all
        let _ = Msg::decode(&payload);
    });
}

#[test]
fn truncation_at_every_offset_is_a_clean_error() {
    check("proto-truncation", 120, |g| {
        let wire = wire_image(g);
        let mut scratch = Vec::new();
        for cut in 0..wire.len() {
            let mut r = Cursor::new(&wire[..cut]);
            let out = read_msg(&mut r, &mut scratch);
            if cut == 0 {
                // nothing arrived: a clean EOF at a message boundary
                assert!(matches!(out, Ok(None)), "empty stream must read as EOF");
            } else {
                assert!(
                    out.is_err(),
                    "a frame cut at byte {cut}/{} must error, not decode",
                    wire.len()
                );
            }
        }
    });
}

#[test]
fn single_bit_flips_never_panic_the_decoder() {
    check("proto-bit-flips", 300, |g| {
        let mut wire = wire_image(g);
        let bit = g.usize(0, wire.len() * 8 - 1);
        wire[bit / 8] ^= 1u8 << (bit % 8);
        let mut scratch = Vec::new();
        // a flip may still decode (a toggled sample bit is a different
        // but valid frame) or error — either way the decoder returns,
        // and the header length check bounds any allocation to
        // MAX_MSG_BYTES before read_exact fails on the short stream
        let _ = read_msg(&mut Cursor::new(&wire[..]), &mut scratch);
        assert!(
            scratch.capacity() <= MAX_MSG_BYTES,
            "scratch grew past the wire cap"
        );
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_any_payload_read() {
    check("proto-oversized-header", 200, |g| {
        // a length strictly above the cap, up to u32::MAX
        let span = (u32::MAX as u64) - (MAX_MSG_BYTES as u64);
        let len = MAX_MSG_BYTES as u64 + 1 + g.rng.next_u64() % span;
        let mut wire = (len as u32).to_le_bytes().to_vec();
        // follow with some bytes that must never be consumed
        wire.extend_from_slice(&[0u8; 16]);
        let mut r = Cursor::new(&wire[..]);
        let mut scratch = Vec::new();
        let out = read_msg(&mut r, &mut scratch);
        assert!(out.is_err(), "length {len} must be rejected");
        assert_eq!(
            r.position(),
            4,
            "the oversized header is rejected before any payload byte is read"
        );
        assert!(scratch.is_empty(), "no allocation for a rejected length");
    });
}
