//! Float↔fixed serving parity (DESIGN.md §13): the integer
//! `FixedEngine` against the float `CpuEngine` on one synthetic
//! workload, across every lane shape that can host a backend — local
//! single-lane, sharded, and a remote loopback node speaking the v4
//! q15 wire format.
//!
//! Two kinds of claim, deliberately separated:
//!
//! * **Bit-exact claims** — the fixed engine against *itself* across
//!   lane shapes. Local, sharded and remote-q15 runs must produce
//!   bit-identical decisions and scores (the workload is pre-snapped to
//!   the q15 grid, so the wire codec is the identity and the remote
//!   check runs through the chaos [`Invariants`] contract).
//! * **Statistical claims** — fixed against float. Quantisation moves
//!   margins, so decisions may differ near the boundary; the suite pins
//!   a decision-agreement floor and a mean-margin-error ceiling
//!   (constants below) and prints the observed stats for trend-watching
//!   in CI logs.

use infilter::coordinator::dispatch::{Lane, PipelineBuilder};
use infilter::coordinator::shard::ShardedPipeline;
use infilter::coordinator::{ClassifyResult, FrameTask};
use infilter::dsp::multirate::BandPlan;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::mp::filter::MpMultirateBank;
use infilter::mp::machine::{Params, Standardizer};
use infilter::net::node::pipeline_factory;
use infilter::net::proto::{dequantize_q, quantize_q15_vec};
use infilter::net::{
    serve_node_until, Invariants, NodeConfig, NodeShutdown, RemoteConfig, RemoteLane, WireFormat,
};
use infilter::runtime::backend::CpuEngine;
use infilter::runtime::fixed::FixedEngine;
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::time::Instant;

const FRAME_LEN: usize = 64;
const CLIP_FRAMES: usize = 2;
const BITS: u32 = 12;
const ACC_BITS: u32 = 24;
const N_STREAMS: u64 = 4;
const CLIPS_PER_STREAM: u64 = 8;

/// Pinned floor on CpuEngine↔FixedEngine decision agreement over the
/// parity workload. The 12-bit datapath tracks float features at
/// cosine > 0.98 (`fixed::pipeline` tests), so real agreement sits far
/// above this; the floor is set where a breach can only mean a broken
/// datapath, not an unlucky workload.
const MIN_DECISION_AGREEMENT: f64 = 0.6;

/// Pinned ceiling on the mean |float margin − dequantised fixed
/// margin| across all heads and clips. Margins live on the
/// standardised-feature scale (the k-format spans ±4.0), so a mean
/// error beyond this is structural, not rounding.
const MAX_MEAN_MARGIN_ERROR: f64 = 1.5;

struct Setup {
    plan: BandPlan,
    model: TrainedModel,
    fixed: FixedPipeline,
}

/// One deterministic calibration: shared plan, shared float
/// params/standardiser (the model the CPU engine serves), and the
/// fixed-point pipeline quantised from exactly those floats.
fn setup() -> Setup {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    let feats = plan.n_filters();
    let mut rng = Pcg32::new(7);
    let params = Params {
        wp: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        wm: (0..2).map(|_| rng.normal_vec(feats)).collect(),
        bp: vec![0.1, -0.2],
        bm: vec![-0.1, 0.2],
    };
    let mut bank = MpMultirateBank::new(&plan, 1.0);
    let phis: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            bank.reset();
            let clip: Vec<f32> = Pcg32::new(100 + i)
                .normal_vec(512)
                .iter()
                .map(|x| 0.3 * x)
                .collect();
            bank.features(&clip)
        })
        .collect();
    let std = Standardizer::fit(&phis);
    let fixed = FixedPipeline::build(
        &plan,
        1.0,
        4.0,
        &params,
        &std,
        &phis,
        FixedConfig::with_bits(BITS),
    );
    let model = TrainedModel {
        classes: vec!["c0".into(), "c1".into()],
        params,
        std,
        gamma_f: 1.0,
        gamma_1: 4.0,
    };
    Setup { plan, model, fixed }
}

fn fixed_engine(s: &Setup) -> FixedEngine {
    FixedEngine::new(s.fixed.clone(), FRAME_LEN, CLIP_FRAMES, ACC_BITS)
        .expect("the parity configuration certifies")
}

fn cpu_engine(s: &Setup) -> CpuEngine {
    CpuEngine::with_clip(&s.plan, s.model.gamma_f, FRAME_LEN, CLIP_FRAMES)
}

/// The shared workload, pre-snapped to the q1.15 grid so the remote
/// q15 leg transports it losslessly and every lane shape sees
/// bit-identical samples.
fn tasks() -> Vec<FrameTask> {
    let mut out = Vec::new();
    for stream in 0..N_STREAMS {
        for clip in 0..CLIPS_PER_STREAM {
            let mut rng = Pcg32::substream(271 ^ clip.wrapping_mul(31), stream);
            for frame_idx in 0..CLIP_FRAMES {
                let raw: Vec<f32> = (0..FRAME_LEN).map(|_| (rng.normal() * 0.25) as f32).collect();
                out.push(FrameTask {
                    stream,
                    clip_seq: clip,
                    frame_idx,
                    data: dequantize_q(15, &quantize_q15_vec(&raw)),
                    label: (stream % 2) as usize,
                    t_gen: Instant::now(),
                });
            }
        }
    }
    out
}

fn by_clip(mut results: Vec<ClassifyResult>) -> Vec<ClassifyResult> {
    results.sort_by_key(|r| (r.stream, r.clip_seq));
    results
}

fn run_local<B>(backend: B, model: &TrainedModel) -> Vec<ClassifyResult>
where
    B: infilter::runtime::backend::InferenceBackend,
{
    let mut lane = PipelineBuilder::new(backend, model.clone())
        .queue_capacity(64)
        .build();
    for t in tasks() {
        assert!(lane.push(t), "local lane dropped a frame");
    }
    lane.drain().unwrap();
    let (report, results) = lane.finish();
    assert_eq!(report.clips_classified, N_STREAMS * CLIPS_PER_STREAM);
    by_clip(results)
}

fn run_sharded(s: &Setup) -> Vec<ClassifyResult> {
    let eng = fixed_engine(s);
    let mut lane = ShardedPipeline::builder(2, move |_| Ok(eng.clone()), s.model.clone())
        .queue_capacity(64)
        .build()
        .unwrap();
    for t in tasks() {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    let (report, results) = Lane::finish(lane).unwrap();
    assert_eq!(report.clips_classified, N_STREAMS * CLIPS_PER_STREAM);
    by_clip(results)
}

/// Remote loopback leg: a node hosting the fixed engine behind TCP,
/// the gateway speaking the v4 q15 payload, the round judged by the
/// chaos accounting contract.
fn run_remote(s: &Setup, reference: &[ClassifyResult]) -> Vec<ClassifyResult> {
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let fp = s.model.fingerprint();
    let stop = NodeShutdown::new();
    let node = std::thread::spawn({
        let stop = stop.clone();
        let eng = fixed_engine(s);
        let model = s.model.clone();
        move || {
            serve_node_until(
                listener,
                pipeline_factory(eng, model, 64),
                fp,
                NodeConfig {
                    credits: 32,
                    ..NodeConfig::default()
                },
                Some(1),
                stop,
            )
            .expect("node serving");
        }
    });
    let rcfg = RemoteConfig {
        wire_format: WireFormat::Q15,
        ..RemoteConfig::default()
    };
    let mut lane = RemoteLane::connect(&addr, fp, rcfg).expect("loopback connect");
    assert_eq!(
        lane.handshake().wire_format,
        WireFormat::Q15,
        "the node must adopt the gateway's q15 proposal"
    );
    for t in tasks() {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    let (report, results) = lane.finish().unwrap();
    stop.shutdown();
    node.join().unwrap();
    let inv = Invariants::new(N_STREAMS * CLIPS_PER_STREAM).lossless().exact();
    inv.assert_ok(&report);
    inv.assert_results(&report, &results, reference);
    by_clip(results)
}

fn assert_bit_identical(tag: &str, a: &[ClassifyResult], b: &[ClassifyResult]) {
    assert_eq!(a.len(), b.len(), "{tag}: clip count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(
            (x.stream, x.clip_seq),
            (y.stream, y.clip_seq),
            "{tag}: clip identity"
        );
        assert_eq!(
            x.predicted, y.predicted,
            "{tag}: decision diverged (stream {} clip {})",
            x.stream, x.clip_seq
        );
        assert_eq!(x.p.len(), y.p.len(), "{tag}: head count");
        for (h, (pa, pb)) in x.p.iter().zip(&y.p).enumerate() {
            assert_eq!(
                pa.to_bits(),
                pb.to_bits(),
                "{tag}: margin bits diverged (stream {} clip {} head {h}): {pa} vs {pb}",
                x.stream,
                x.clip_seq
            );
        }
    }
}

#[test]
fn fixed_engine_is_bit_identical_across_local_sharded_and_remote_q15_lanes() {
    let s = setup();
    let local = run_local(fixed_engine(&s), &s.model);
    let sharded = run_sharded(&s);
    assert_bit_identical("sharded vs local", &sharded, &local);
    let remote = run_remote(&s, &local);
    assert_bit_identical("remote-q15 vs local", &remote, &local);
}

#[test]
fn fixed_and_float_engines_agree_within_the_pinned_bounds() {
    let s = setup();
    let fixed = run_local(fixed_engine(&s), &s.model);
    let cpu = run_local(cpu_engine(&s), &s.model);
    assert_eq!(fixed.len(), cpu.len());

    let total = fixed.len();
    let mut agree = 0usize;
    let mut err_sum = 0.0f64;
    let mut err_max = 0.0f64;
    let mut err_n = 0usize;
    for (f, c) in fixed.iter().zip(&cpu) {
        assert_eq!((f.stream, f.clip_seq), (c.stream, c.clip_seq));
        if f.predicted == c.predicted {
            agree += 1;
        }
        assert_eq!(f.p.len(), c.p.len());
        for (pf, pc) in f.p.iter().zip(&c.p) {
            assert!(pf.is_finite(), "fixed margin not finite");
            assert!(pc.is_finite(), "float margin not finite");
            let e = (f64::from(*pf) - f64::from(*pc)).abs();
            err_sum += e;
            err_max = err_max.max(e);
            err_n += 1;
        }
    }
    let agreement = agree as f64 / total as f64;
    let mean_err = err_sum / err_n as f64;
    eprintln!(
        "fixed-parity: {agree}/{total} decisions agree ({:.1}%), margin error mean {mean_err:.4} \
         max {err_max:.4} (W={BITS}, acc={ACC_BITS})",
        agreement * 100.0
    );
    assert!(
        agreement >= MIN_DECISION_AGREEMENT,
        "float↔fixed decision agreement {agreement:.3} fell below the pinned \
         {MIN_DECISION_AGREEMENT} floor — quantised datapath has drifted structurally"
    );
    assert!(
        mean_err <= MAX_MEAN_MARGIN_ERROR,
        "float↔fixed mean margin error {mean_err:.4} exceeds the pinned \
         {MAX_MEAN_MARGIN_ERROR} ceiling"
    );
}
