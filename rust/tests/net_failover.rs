//! Failure-path acceptance for cross-process serving, tier-1 safe
//! (loopback TCP, port 0, no external network): concurrent gateway
//! sessions on one node, mid-stream link death with reconnect, pool
//! re-routing around a dead node, and the degraded accounting when a
//! node never comes back. The at-most-once contract under test is
//! specified in docs/WIRE.md; docs/OPERATIONS.md tabulates the
//! observable behaviour these tests pin down.

use infilter::coordinator::dispatch::{Lane, PipelineBuilder};
use infilter::coordinator::{ClassifyResult, FrameTask};
use infilter::dsp::multirate::BandPlan;
use infilter::net::node::pipeline_factory;
use infilter::net::{serve_node, Invariants, NodeConfig, RemoteConfig, RemoteLane, RemotePool};
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::train::TrainedModel;
use infilter::util::prng::Pcg32;
use std::net::TcpListener;
use std::time::{Duration, Instant};

fn engine() -> CpuEngine {
    let mut plan = BandPlan::paper_default();
    plan.n_octaves = 2;
    CpuEngine::with_clip(&plan, 1.0, 64, 2)
}

fn model() -> TrainedModel {
    TrainedModel::synthetic(11, 4, engine().n_filters(), 0.0, 1.0)
}

/// Deterministic per-stream clips: the same (stream, clip) pair always
/// produces the same samples, so remote runs can be compared bit-wise
/// against local runs clip by clip.
fn clip_frames(stream: u64, clip: u64) -> Vec<FrameTask> {
    let mut rng = Pcg32::substream(97 ^ clip.wrapping_mul(31), stream);
    (0..2usize)
        .map(|f| FrameTask {
            stream,
            clip_seq: clip,
            frame_idx: f,
            data: (0..64).map(|_| (rng.normal() * 0.1) as f32).collect(),
            label: (stream % 4) as usize,
            t_gen: Instant::now(),
        })
        .collect()
}

fn spawn_node(
    m: TrainedModel,
    cfg: NodeConfig,
    conns: usize,
) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bind");
    let addr = listener.local_addr().unwrap().to_string();
    let fp = m.fingerprint();
    let handle = std::thread::spawn(move || {
        serve_node(listener, pipeline_factory(engine(), m, 64), fp, cfg, Some(conns))
            .expect("node serving");
    });
    (addr, handle)
}

/// Classify the given (stream, clip) pairs on a local in-process
/// pipeline — the bit-parity reference.
fn local_reference(m: &TrainedModel, clips: &[(u64, u64)]) -> Vec<ClassifyResult> {
    let mut lane = PipelineBuilder::new(engine(), m.clone())
        .queue_capacity(64)
        .build();
    for &(s, c) in clips {
        for t in clip_frames(s, c) {
            assert!(Lane::push(&mut lane, t));
        }
    }
    Lane::drain(&mut lane).unwrap();
    let (_, results) = Lane::finish(lane).unwrap();
    sorted(results)
}

fn sorted(mut rs: Vec<ClassifyResult>) -> Vec<ClassifyResult> {
    rs.sort_by_key(|r| (r.stream, r.clip_seq));
    rs
}

fn assert_bit_parity(remote: &[ClassifyResult], local: &[ClassifyResult]) {
    assert_eq!(remote.len(), local.len());
    for (a, b) in remote.iter().zip(local) {
        assert_eq!((a.stream, a.clip_seq), (b.stream, b.clip_seq));
        assert_eq!(a.predicted, b.predicted, "stream {} clip {}", a.stream, a.clip_seq);
        assert_eq!(
            a.p, b.p,
            "remote scores must be bit-equal (stream {} clip {})",
            a.stream, a.clip_seq
        );
    }
}

fn fast_reconnect() -> RemoteConfig {
    RemoteConfig {
        reconnect_attempts: 4,
        reconnect_backoff: Duration::from_millis(5),
        ..RemoteConfig::default()
    }
}

#[test]
fn two_concurrent_gateways_match_local_bit_exactly() {
    // one node, two gateways alive at the same time — under the old
    // sequential accept loop gateway B's handshake would block until A
    // finished, and B's drain below would deadlock
    let m = model();
    let (addr, node) = spawn_node(
        m.clone(),
        NodeConfig {
            credits: 16,
            max_sessions: 2,
            ..NodeConfig::default()
        },
        2,
    );
    let mut a = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    let mut b = RemoteLane::connect(&addr, m.fingerprint(), RemoteConfig::default()).unwrap();
    assert_ne!(a.session_id(), b.session_id());
    let a_clips: Vec<(u64, u64)> = (0..4u64).flat_map(|s| [(s, 0u64), (s, 1)]).collect();
    let b_clips: Vec<(u64, u64)> = (10..16u64).map(|s| (s, 0u64)).collect();
    // interleave pushes across the two live sessions
    for i in 0..a_clips.len().max(b_clips.len()) {
        if let Some(&(s, c)) = a_clips.get(i) {
            for t in clip_frames(s, c) {
                assert!(a.push(t));
            }
        }
        if let Some(&(s, c)) = b_clips.get(i) {
            for t in clip_frames(s, c) {
                assert!(b.push(t));
            }
        }
    }
    // both barriers while both sessions are open
    a.drain().unwrap();
    b.drain().unwrap();
    assert_eq!(a.clips_classified(), 8);
    assert_eq!(b.clips_classified(), 6);
    let (ra, results_a) = a.finish().unwrap();
    let (rb, results_b) = b.finish().unwrap();
    node.join().unwrap();
    // both sessions ran clean: the shared accounting checker demands
    // full classification with zero loss on each
    Invariants::new(8).lossless().exact().assert_ok(&ra);
    Invariants::new(6).lossless().exact().assert_ok(&rb);
    assert_bit_parity(&sorted(results_a), &local_reference(&m, &a_clips));
    assert_bit_parity(&sorted(results_b), &local_reference(&m, &b_clips));
}

#[test]
fn lane_reconnects_after_link_death_and_completes_the_stream() {
    // clean kill at a barrier: nothing in flight, so the run completes
    // with zero loss across two node sessions, and the merged counters
    // span both
    let m = model();
    let (addr, node) = spawn_node(m.clone(), NodeConfig::default(), 2);
    let mut lane = RemoteLane::connect(&addr, m.fingerprint(), fast_reconnect()).unwrap();
    let first_session = lane.session_id();
    let clips0: Vec<(u64, u64)> = (0..4u64).map(|s| (s, 0u64)).collect();
    let clips1: Vec<(u64, u64)> = (0..4u64).map(|s| (s, 1u64)).collect();
    for &(s, c) in &clips0 {
        for t in clip_frames(s, c) {
            assert!(lane.push(t));
        }
    }
    lane.drain().unwrap();
    assert_eq!(lane.clips_classified(), 4);

    lane.inject_link_failure();
    // wait until the lane has observed the death and re-established the
    // session (poll_ready runs the backoff-gated reconnect machinery)
    while lane.reconnects() == 0 {
        let _ = lane.poll_ready();
        std::thread::sleep(Duration::from_millis(1));
    }

    // pushes after the death transparently land on a fresh session
    for &(s, c) in &clips1 {
        for t in clip_frames(s, c) {
            assert!(lane.push(t), "push must reconnect, not drop");
        }
    }
    assert_ne!(lane.session_id(), first_session, "a fresh node session");
    assert_eq!(lane.reconnects(), 1);
    lane.drain().unwrap();
    assert_eq!(lane.clips_classified(), 8);
    let (report, results) = lane.finish().unwrap();
    node.join().unwrap();
    assert_eq!(report.reconnects, 1);
    // nothing was in flight at the kill, so the run must be lossless
    // across both node sessions
    Invariants::new(8).lossless().exact().assert_ok(&report);
    // results from before and after the failover are all bit-exact
    let all: Vec<(u64, u64)> = clips0.iter().chain(&clips1).copied().collect();
    assert_bit_parity(&sorted(results), &local_reference(&m, &all));
}

#[test]
fn midflight_kill_accounts_every_clip_exactly_once() {
    // kill with work in flight: whether each clip's result beat the
    // kill is timing-dependent, but the at-most-once accounting must
    // make the outcomes sum exactly — classified + aborted = pushed
    let m = model();
    let (addr, node) = spawn_node(m.clone(), NodeConfig::default(), 2);
    let mut lane = RemoteLane::connect(&addr, m.fingerprint(), fast_reconnect()).unwrap();
    for s in 0..3u64 {
        for t in clip_frames(s, 0) {
            assert!(lane.push(t));
        }
    }
    lane.inject_link_failure();
    lane.drain().unwrap(); // reconnects (or settles vacuously)
    let (report, results) = lane.finish().unwrap();
    node.join().unwrap();
    assert_eq!(report.reconnects, 1);
    // every pushed clip resolves exactly once (classified or aborted),
    // and whatever was delivered is bit-identical to a local run — the
    // same contract the chaos rounds check under injected faults
    let inv = Invariants::new(3).exact();
    inv.assert_ok(&report);
    let clips: Vec<(u64, u64)> = (0..3u64).map(|s| (s, 0u64)).collect();
    inv.assert_results(&report, &sorted(results), &local_reference(&m, &clips));
}

#[test]
fn pool_reroutes_streams_of_a_dead_node_to_the_survivor() {
    let m = model();
    let (addr_a, node_a) = spawn_node(m.clone(), NodeConfig::default(), 1);
    let (addr_b, node_b) = spawn_node(m.clone(), NodeConfig::default(), 1);
    let mut pool =
        RemotePool::connect(&[addr_a, addr_b], m.fingerprint(), fast_reconnect()).unwrap();
    // one stream homed on each node
    let sa = (0..64u64).find(|&s| pool.route(s) == 0).unwrap();
    let sb = (0..64u64).find(|&s| pool.route(s) == 1).unwrap();
    for &s in &[sa, sb] {
        for t in clip_frames(s, 0) {
            assert!(pool.push(t));
        }
    }
    Lane::drain(&mut pool).unwrap();
    assert_eq!(pool.clips_classified(), 2);

    // node A dies for good (max_conns=1: its listener is gone too)
    pool.lane_mut(0).inject_link_failure();
    node_a.join().unwrap();
    // wait until lane 0 has observed the death (after which its one
    // backoff-gated reconnect attempt fails fast on the closed port)
    while pool.lane_mut(0).poll_ready() {
        std::thread::sleep(Duration::from_millis(1));
    }

    // new clips for BOTH streams: sa's home is down, so its clip must
    // re-route to node B and still classify bit-exactly
    for &s in &[sa, sb] {
        for t in clip_frames(s, 1) {
            assert!(pool.push(t), "re-route must absorb the dead node");
        }
    }
    Lane::drain(&mut pool).unwrap();
    assert_eq!(pool.clips_classified(), 4);
    let (report, results) = Lane::finish(pool).unwrap();
    node_b.join().unwrap();
    // merged report covers both nodes, stays lossless through the
    // re-route, and its per-lane rows sum to the pool totals
    Invariants::new(4).lossless().exact().pool(2).assert_ok(&report);
    let reference = local_reference(&m, &[(sa, 0), (sa, 1), (sb, 0), (sb, 1)]);
    assert_bit_parity(&sorted(results), &reference);
}

#[test]
fn exhausted_reconnect_degrades_to_gateway_side_accounting() {
    // the node never comes back: pushes drop (accounted), barriers are
    // vacuous, and finish still returns a consistent report instead of
    // an error — a RemotePool merge must be able to account dead lanes
    let m = model();
    let (addr, node) = spawn_node(m.clone(), NodeConfig::default(), 1);
    let cfg = RemoteConfig {
        reconnect_attempts: 2,
        reconnect_backoff: Duration::from_millis(1),
        reconnect_max_backoff: Duration::from_millis(4),
        ..RemoteConfig::default()
    };
    let mut lane = RemoteLane::connect(&addr, m.fingerprint(), cfg).unwrap();
    for t in clip_frames(7, 0) {
        assert!(lane.push(t));
    }
    lane.drain().unwrap();
    assert_eq!(lane.clips_classified(), 1);
    lane.inject_link_failure();
    node.join().unwrap(); // the listener is gone: reconnects must fail
    let mut dropped = 0u64;
    for t in clip_frames(7, 1) {
        if !lane.push(t) {
            dropped += 1;
        }
    }
    assert!(dropped > 0, "a dead node with no listener sheds pushes");
    lane.drain().unwrap(); // vacuous, not an error
    let (report, results) = lane.finish().unwrap();
    assert_eq!(report.clips_classified, 1, "pre-kill result retained");
    assert_eq!(results.len(), 1);
    // two clips were offered in total; the base contract still holds
    // in the fully degraded state
    Invariants::new(2).assert_ok(&report);
    // every shed push surfaced in a loss counter: as a dropped frame,
    // or folded into its clip's abort when the write died buffered
    assert!(
        report.frames_dropped + report.clips_aborted >= dropped,
        "losses accounted (dropped_frames {} + aborted {} >= {dropped})",
        report.frames_dropped,
        report.clips_aborted
    );
}
