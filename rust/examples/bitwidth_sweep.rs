//! Bit-width design-space exploration (extends paper Fig. 8): sweep the
//! datapath width and report accuracy together with the modelled FPGA
//! cost, i.e. the accuracy/area Pareto front a hardware designer needs.
//!
//!     cargo run --release --example bitwidth_sweep -- [--scale S]

use anyhow::Result;
use infilter::datasets::esc10;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::fpga::resources::{estimate, ArchParams, CostModel};
use infilter::mp::machine::Standardizer;
use infilter::runtime::engine::ModelEngine;
use infilter::train::{train_heads, TrainConfig};
use infilter::util::cli::Args;
use infilter::util::par::par_map;
use infilter::util::prng::Pcg32;
use infilter::util::table::Table;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    infilter::util::logging::set_level_from_str(args.get_or("log", "warn"));
    let scale = args.get_f64("scale", 0.15);
    let threads = args.get_usize(
        "threads",
        std::thread::available_parallelism().map_or(4, |n| n.get()),
    );

    let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0)?;
    let clip_len = eng.frame_len() * eng.clip_frames();

    // balanced crying-baby task, float-trained reference model
    let ds = esc10::build(42, scale);
    let class = 3;
    let mut rng = Pcg32::new(0x5eed);
    let pick = |clips: &[infilter::datasets::Clip], rng: &mut Pcg32| {
        let pos: Vec<_> = clips.iter().filter(|c| c.label == class).cloned().collect();
        let negp: Vec<_> = clips.iter().filter(|c| c.label != class).cloned().collect();
        let idx = rng.sample_indices(negp.len(), pos.len().min(negp.len()));
        let mut out = pos.clone();
        let mut y = vec![true; pos.len()];
        for i in idx {
            out.push(negp[i].clone());
            y.push(false);
        }
        for c in out.iter_mut() {
            c.samples.truncate(clip_len);
        }
        (out, y)
    };
    let (tr, tr_y) = pick(&ds.train, &mut rng);
    let (te, te_y) = pick(&ds.test, &mut rng);

    let phi = eng.clip_features_many(&tr.iter().map(|c| c.samples.as_slice()).collect::<Vec<_>>())?;
    let std = Standardizer::fit(&phi);
    let k = std.apply_all(&phi);
    let targets: Vec<Vec<f32>> = tr_y
        .iter()
        .map(|&p| if p { vec![1.0, 0.0] } else { vec![0.0, 1.0] })
        .collect();
    let cfg = TrainConfig::default();
    let (params, _) = train_heads(&mut eng, &k, &targets, 2, &cfg)?;

    let mut table = Table::new(
        "bitwidth sweep: accuracy vs modelled FPGA cost",
        &["bits", "test_acc_%", "LUT", "FF", "slices", "mW@50MHz"],
    );
    let cm = CostModel::default();
    for bits in [4u32, 6, 8, 10, 12, 16] {
        let pipe = FixedPipeline::build(
            &eng.plan, 1.0, cfg.gamma_end, &params, &std, &phi,
            FixedConfig::with_bits(bits),
        );
        let preds = par_map(&te, threads, |c| {
            let m = pipe.classify(&c.samples);
            m[0] > m[1]
        });
        let acc = preds.iter().zip(&te_y).filter(|(p, y)| p == y).count() as f64
            / te_y.len().max(1) as f64;
        let mut arch = ArchParams::paper_default();
        arch.data_bits = bits as usize;
        arch.acc_bits = bits as usize + 14;
        let est = estimate(&arch, &cm);
        table.row(vec![
            bits.to_string(),
            format!("{:.1}", 100.0 * acc),
            est.luts().to_string(),
            est.ffs().to_string(),
            est.slices().to_string(),
            format!("{:.1}", est.power_mw(&cm, 50.0)),
        ]);
    }
    println!("{}", table.render());
    table.write_csv(Path::new("results/bitwidth_sweep.csv"))?;
    println!("bitwidth_sweep OK");
    Ok(())
}
