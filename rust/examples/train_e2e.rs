//! End-to-end validation driver (DESIGN.md §6 "e2e"): proves all three
//! layers compose on a real small workload.
//!
//!     cargo run --release --example train_e2e -- [--scale S] [--epochs E]
//!
//! Pipeline exercised, all through the AOT HLO artifacts (python never
//! runs here):
//!   1. synthesise the ESC-10 workload,
//!   2. extract in-filter MP features with the batched (B=8)
//!      `mp_frame_features` artifact (L1 Pallas kernel inside),
//!   3. train the 10-head one-vs-all MP kernel machine for a few hundred
//!      steps with gamma annealing via `mp_train_step_c10`
//!      (jax.grad through the MP custom_vjp), logging the loss curve,
//!   4. evaluate train/test accuracy with `mp_eval_c10`,
//!   5. quantise to the 8-bit hardware model and re-evaluate — the
//!      paper's headline: 8-bit fixed ~= float.

use anyhow::Result;
use infilter::datasets::esc10;
use infilter::fixed::{FixedConfig, FixedPipeline};
use infilter::runtime::engine::ModelEngine;
use infilter::train::{evaluate, train_model, TrainConfig};
use infilter::util::cli::Args;
use infilter::util::par::par_map;
use infilter::util::table::Table;
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    infilter::util::logging::set_level_from_str(args.get_or("log", "info"));
    let scale = args.get_f64("scale", 0.3);
    let threads = args.get_usize("threads", std::thread::available_parallelism().map_or(4, |n| n.get()));

    let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0)?;
    let clip_len = eng.frame_len() * eng.clip_frames();

    // 1. workload
    let ds = esc10::build(42, scale);
    println!("dataset: {}", ds.summary());

    // 2. features (L1+L2 through PJRT, batched lanes of 8)
    let t0 = Instant::now();
    let tr_samps: Vec<&[f32]> = ds.train.iter().map(|c| &c.samples[..clip_len]).collect();
    let te_samps: Vec<&[f32]> = ds.test.iter().map(|c| &c.samples[..clip_len]).collect();
    let phi_tr = eng.clip_features_many(&tr_samps)?;
    let phi_te = eng.clip_features_many(&te_samps)?;
    let feat_time = t0.elapsed();
    println!(
        "features: {} clips in {:.1}s ({:.2}x realtime)",
        phi_tr.len() + phi_te.len(),
        feat_time.as_secs_f64(),
        (phi_tr.len() + phi_te.len()) as f64 * (clip_len as f64 / 16_000.0)
            / feat_time.as_secs_f64()
    );

    // 3. training (a few hundred steps through mp_train_step_c10)
    let labels_tr: Vec<usize> = ds.train.iter().map(|c| c.label).collect();
    let labels_te: Vec<usize> = ds.test.iter().map(|c| c.label).collect();
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 50),
        ..TrainConfig::default()
    };
    let t1 = Instant::now();
    let (model, losses) = train_model(&mut eng, &phi_tr, &labels_tr, &ds.classes, 1.0, &cfg)?;
    println!(
        "training: {} steps in {:.1}s, loss {:.4} -> {:.4}",
        losses.len(),
        t1.elapsed().as_secs_f64(),
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    // loss curve: print a coarse decimation and dump the full CSV
    let mut t = Table::new("e2e training loss", &["step", "loss"]);
    for (i, l) in losses.iter().enumerate() {
        t.row(vec![i.to_string(), format!("{l:.6}")]);
    }
    t.write_csv(Path::new("results/train_e2e_loss.csv"))?;
    let stride = (losses.len() / 12).max(1);
    for (i, l) in losses.iter().enumerate().step_by(stride) {
        println!("  step {i:>5}  loss {l:.4}");
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "loss did not decrease"
    );

    // 4. float evaluation
    let acc_tr = evaluate(&mut eng, &model, &phi_tr, &labels_tr)?;
    let acc_te = evaluate(&mut eng, &model, &phi_te, &labels_te)?;
    println!(
        "float MP kernel machine: train {:.1}%  test {:.1}% (10-way argmax)",
        100.0 * acc_tr,
        100.0 * acc_te
    );

    // 5. 8-bit hardware model on the same task: per-clip margins argmax.
    // The c10 head params quantise directly; accumulators recomputed by
    // the integer pipeline.
    let t2 = Instant::now();
    let pipe = FixedPipeline::build(
        &eng.plan,
        model.gamma_f,
        model.gamma_1,
        &model.params,
        &model.std,
        &phi_tr,
        FixedConfig::with_bits(8),
    );
    let acc_of = |clips: &[infilter::datasets::Clip], labels: &[usize]| -> f64 {
        let preds = par_map(clips, threads, |c| {
            infilter::util::stats::argmax(&pipe.classify(&c.samples[..clip_len]))
        });
        preds
            .iter()
            .zip(labels)
            .filter(|(p, l)| p == l)
            .count() as f64
            / labels.len().max(1) as f64
    };
    let fx_te = acc_of(&ds.test, &labels_te);
    println!(
        "8-bit fixed-point hardware model: test {:.1}% ({:.1}s)",
        100.0 * fx_te,
        t2.elapsed().as_secs_f64()
    );
    println!(
        "float vs 8-bit gap: {:.1} points (paper: ~0-2 points)",
        100.0 * (acc_te - fx_te).abs()
    );
    println!("train_e2e OK");
    Ok(())
}
