//! Quickstart: load the AOT artifacts, extract in-filter MP features
//! from one synthetic clip, and classify it with a freshly trained
//! 2-class MP kernel machine.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Everything below the `ModelEngine::open` line is pure rust — python
//! only ran at build time to lower the HLO.

use anyhow::Result;
use infilter::datasets::esc10;
use infilter::mp::machine::Standardizer;
use infilter::runtime::engine::ModelEngine;
use infilter::train::{train_heads, TrainConfig};
use std::path::Path;

fn main() -> Result<()> {
    // 1. open the PJRT runtime on the AOT artifacts
    let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0)?;
    let clip_len = eng.frame_len() * eng.clip_frames();
    println!(
        "engine: {} filters, frame {} samples, clip {} samples",
        eng.n_filters(),
        eng.frame_len(),
        clip_len
    );

    // 2. a tiny balanced task: crying_baby (class 3) vs dog (class 0)
    let mut clips = Vec::new();
    let mut labels = Vec::new();
    for i in 0..12u64 {
        for (class, pos) in [(3usize, true), (0usize, false)] {
            let mut c = esc10::synth_clip(7, class, i);
            c.samples.truncate(clip_len);
            clips.push(c);
            labels.push(pos);
        }
    }

    // 3. in-filter MP features through the mp_frame_features HLO
    let phi =
        eng.clip_features_many(&clips.iter().map(|c| c.samples.as_slice()).collect::<Vec<_>>())?;
    println!("extracted {} feature vectors of dim {}", phi.len(), phi[0].len());

    // 4. train the MP kernel machine via the AOT train-step artifact
    let std = Standardizer::fit(&phi);
    let k = std.apply_all(&phi);
    let targets: Vec<Vec<f32>> = labels
        .iter()
        .map(|&p| if p { vec![1.0, 0.0] } else { vec![0.0, 1.0] })
        .collect();
    let cfg = TrainConfig {
        epochs: 25,
        ..TrainConfig::default()
    };
    let (params, losses) = train_heads(&mut eng, &k, &targets, 2, &cfg)?;
    println!(
        "trained: loss {:.4} -> {:.4} over {} steps",
        losses.first().unwrap(),
        losses.last().unwrap(),
        losses.len()
    );

    // 5. classify a fresh clip end to end (features + inference HLOs)
    let mut probe = esc10::synth_clip(99, 3, 1234);
    probe.samples.truncate(clip_len);
    let phi_probe = eng.clip_features(&probe.samples)?;
    let (p, zp, zm) = eng.inference(&params, &std, &phi_probe, cfg.gamma_end)?;
    println!("decision p = {p:?} (z+ = {zp:?}, z- = {zm:?})");
    let verdict = if p[0] > p[1] { "crying_baby" } else { "not crying_baby" };
    println!("verdict: {verdict}");
    assert!(p[0] > p[1], "expected the crying-baby head to win");
    println!("quickstart OK");
    Ok(())
}
