//! Wildlife monitoring scenario (paper Fig. 1), now on the real edge
//! ingest subsystem: remote duty-cycled sensors hear continuous ambient
//! audio, a multiplierless energy gate (add/shift/compare only — the
//! same primitives as the MP datapath) triggers on sparse events, the
//! per-sensor session assembles clip-aligned frames with pre-trigger
//! lookback, the coordinator classifies them on-node, and only tiny
//! event reports cross the token-bucket-limited uplink.
//!
//!     cargo run --release --example wildlife_monitor -- \
//!         [--streams N] [--shards N] [--seconds S] [--events K] [--scale S]
//!
//! Runs entirely on the pure-rust CPU backend: no AOT artifacts needed.
//! With `--shards N` the fleet classifies on N compute lanes (one
//! CpuEngine each, stream-hash routed) and the report shows the
//! per-lane frame counts.

use anyhow::Result;
use infilter::config::EdgeConfig;
use infilter::datasets::esc10;
use infilter::dsp::multirate::BandPlan;
use infilter::edge::fleet::{fleet_lane, run_fleet, FleetConfig};
use infilter::edge::AMBIENT_LABEL;
use infilter::runtime::backend::{CpuEngine, InferenceBackend};
use infilter::train::{evaluate_cpu, train_model_cpu, TrainConfig};
use infilter::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    infilter::util::logging::set_level_from_str(args.get_or("log", "info"));
    let plan = BandPlan::paper_default();
    let eng = CpuEngine::new(&plan, 1.0);
    let clip_len = eng.frame_len() * eng.clip_frames();

    // train the on-node model (pure CPU: MP features + sub-gradient SGD)
    let scale = args.get_f64("scale", 0.05);
    let ds = esc10::build(11, scale);
    println!("training on {}", ds.summary());
    let samps: Vec<&[f32]> = ds.train.iter().map(|c| &c.samples[..clip_len]).collect();
    let threads = std::thread::available_parallelism().map_or(4, |n| n.get());
    let phi = eng.clip_features_many(&samps, threads);
    let labels: Vec<usize> = ds.train.iter().map(|c| c.label).collect();
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 30),
        ..TrainConfig::default()
    };
    let (model, _) = train_model_cpu(&phi, &labels, &ds.classes, 1.0, &cfg);
    let train_acc = evaluate_cpu(&model, &phi, &labels);
    println!("on-node model multiclass train accuracy: {:.1}%", 100.0 * train_acc);

    // the monitoring fleet: continuous audio, gate-triggered clips
    let mut edge = EdgeConfig::from_args(&args);
    if args.get("streams").is_none() {
        edge.n_streams = 12; // example-sized fleet by default
    }
    let fleet = FleetConfig::from_edge(
        &edge,
        23,
        eng.frame_len(),
        eng.clip_frames(),
        eng.sample_rate(),
    );
    println!(
        "monitoring {} sensors x {:.1}s, {} embedded events each, duty {}/{}, \
         {} compute lane(s) ...",
        fleet.n_streams,
        fleet.ticks as f64 * fleet.frame_len as f64 / fleet.sample_rate,
        fleet.events_per_stream,
        fleet.duty_awake,
        fleet.duty_sleep,
        fleet.shards
    );
    // the serving side is one owned compute lane — or N sharded ones
    let lane = fleet_lane(&fleet, model.clone(), move |_| Ok(eng.clone()))?;
    let (report, results) = run_fleet(lane, &fleet)?;
    println!("\n=== edge fleet report ===\n{}", report.render());

    // the data that actually crossed the uplink
    println!("\nuplink payload (sensor, clip, detected class):");
    for r in results.iter().take(12) {
        let verdict = if r.label == AMBIENT_LABEL {
            "false trigger".to_string()
        } else if r.predicted == r.label {
            "ok".to_string()
        } else {
            format!("MISS, was {}", model.class_name(r.label))
        };
        println!(
            "  sensor{:02} clip{} -> {} ({}) p={:+.2}",
            r.stream,
            r.clip_seq,
            model.class_name(r.predicted),
            verdict,
            r.p[r.predicted]
        );
    }
    // with clip uploads enabled the ratio legitimately shrinks, so the
    // 10x floor only applies to the default report-only payload
    if !fleet.uplink.upload_clips {
        assert!(
            report.bytes_saved_ratio > 10.0,
            "edge gating must beat raw streaming 10x, got {:.1}x",
            report.bytes_saved_ratio
        );
    }
    println!("\nwildlife_monitor OK");
    Ok(())
}
