//! Wildlife monitoring scenario (paper Fig. 1): a conservation node
//! serves many remote acoustic sensor streams, classifying every clip
//! on-node so only labels cross the network.
//!
//!     cargo run --release --example wildlife_monitor -- \
//!         [--streams N] [--clips K] [--realtime] [--scale S]
//!
//! Trains a 10-class model on synthetic ESC-10, then runs the streaming
//! coordinator (dynamic batcher + per-stream state manager + single
//! PJRT lane) and prints the serving report: accuracy, latency
//! percentiles, realtime factor and batch occupancy.

use anyhow::Result;
use infilter::coordinator::server::{serve, ServeConfig};
use infilter::datasets::esc10;
use infilter::runtime::engine::ModelEngine;
use infilter::train::{train_model, TrainConfig};
use infilter::util::cli::Args;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    infilter::util::logging::set_level_from_str(args.get_or("log", "info"));
    let mut eng = ModelEngine::open(Path::new("artifacts"), 1.0)?;
    let clip_len = eng.frame_len() * eng.clip_frames();

    // train the on-node model
    let scale = args.get_f64("scale", 0.2);
    let ds = esc10::build(11, scale);
    println!("training on {}", ds.summary());
    let samps: Vec<&[f32]> = ds.train.iter().map(|c| &c.samples[..clip_len]).collect();
    let phi = eng.clip_features_many(&samps)?;
    let labels: Vec<usize> = ds.train.iter().map(|c| c.label).collect();
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 30),
        ..TrainConfig::default()
    };
    let (model, _) = train_model(&mut eng, &phi, &labels, &ds.classes, 1.0, &cfg)?;
    let train_acc = infilter::train::evaluate(&mut eng, &model, &phi, &labels)?;
    println!("on-node model multiclass train accuracy: {:.1}%", 100.0 * train_acc);

    // serve sensor streams
    let scfg = ServeConfig {
        n_streams: args.get_usize("streams", 8),
        clips_per_stream: args.get_usize("clips", 4),
        seed: 23,
        realtime: args.flag("realtime"),
        ..Default::default()
    };
    println!(
        "serving {} sensor streams x {} clips (realtime={})...",
        scfg.n_streams, scfg.clips_per_stream, scfg.realtime
    );
    let (report, results) = serve(&mut eng, &model, &scfg)?;
    println!("\n=== serving report ===\n{}", report.render());

    // per-stream detections, the data that would cross the uplink
    println!("\nuplink payload (stream, clip, detected class):");
    for r in results.iter().take(12) {
        println!(
            "  sensor{:02} clip{} -> {} ({}) p={:+.2} lat={:.0}ms",
            r.stream,
            r.clip_seq,
            model.classes[r.predicted],
            if r.predicted == r.label { "ok" } else { "MISS" },
            r.p[r.predicted],
            r.latency.as_secs_f64() * 1e3
        );
    }
    assert_eq!(
        report.clips_classified,
        (scfg.n_streams * scfg.clips_per_stream) as u64
    );
    println!("wildlife_monitor OK");
    Ok(())
}
